//! The coordinator service: leader + scheduler + worker pool.
//!
//! Lifecycle:
//!
//! ```text
//! client --submit()--> submit queue --scheduler (drain+coalesce)--> job
//!        <-Receiver--- worker pool  <----- executor injector <------+
//! ```
//!
//! * The **scheduler** thread drains the submit queue, coalesces requests
//!   sharing a matrix into multi-RHS jobs ([`super::batch`]), and feeds
//!   the [`crate::parallel::Executor`]'s bounded injector (backpressure
//!   propagates to submitters).
//! * The **executor**'s workers pull jobs, route them ([`super::router`]),
//!   and run the backend with panic isolation per job (a panicking solve
//!   is counted in `worker_panics` and its clients get a dropped-channel
//!   error; the worker survives). Batched jobs amortise shared work: QR
//!   factors the matrix once per job; the CD solvers compute column norms
//!   once per job. Worker count comes from
//!   [`CoordinatorConfig::workers`], whose default honours
//!   `PALLAS_THREADS` ([`crate::parallel::default_threads`]).
//! * Every request gets its own `mpsc` reply channel; [`Coordinator::submit`]
//!   returns the receiver.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::api::{
    report_from_coefficients, solver_for, PjrtSolver, Problem, Solver, SolverError, SolverKind,
};
use crate::baselines::qr;
use crate::linalg::Mat;
use crate::parallel::Executor;
use crate::runtime::Engine;
use crate::solver::{self, SolveReport};
use crate::util::log::{emit, emit_traced, Level};

use crate::obs::{MultiProbe, ProbeHandle, RingProbe, SolveProbe, Telemetry, TraceCtx, TraceRing};
use crate::robust::{CancelToken, Checkpoint, CheckpointProbe, Watchdog};

use super::batch::{coalesce, BatchPolicy};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::{SharedMatrix, SolveJob, SolveOutcome, SolveRequest};
use super::router::route;

/// Points kept per traced solve's convergence trajectory (the probe
/// downsamples past this, never reallocates).
const TRACE_TRAJECTORY_CAP: usize = 256;

/// Completed traced solves retained for the server's `traces` command.
const TRACE_RING_CAP: usize = 64;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads executing jobs. The default honours the
    /// `PALLAS_THREADS` environment variable, then the machine's
    /// available parallelism ([`crate::parallel::default_threads`]).
    pub workers: usize,
    /// Submit-queue capacity (backpressure bound).
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Artifact directory; enables the PJRT backend when present & valid.
    pub artifact_dir: Option<PathBuf>,
    /// Admission control: max concurrently admitted [`Coordinator::submit_robust`]
    /// requests. `0` disables the gate (every request admitted).
    pub max_inflight: usize,
    /// How long a robust submission may wait for a permit before the gate
    /// sheds (or degrades) it. `0` = don't wait: shed immediately.
    pub max_queue_wait_ms: u64,
    /// When set, a saturated gate answers with a reduced-sweep BAK solve
    /// (capped at this many sweeps) instead of shedding the request.
    pub degraded_sweeps: Option<usize>,
    /// Durable job journal directory. When set, requests carrying a
    /// [`SolveRequest::job_id`] checkpoint their iterate here every
    /// [`CoordinatorConfig::checkpoint_every`] sweeps and resume from a
    /// compatible `.ckpt` on re-submission (same id, solver, seed and
    /// shape). `None` disables the journal entirely.
    pub journal_dir: Option<PathBuf>,
    /// Sweeps between journal checkpoints (clamped to at least 1).
    pub checkpoint_every: usize,
    /// Numerical-health watchdog thresholds, applied to every journaled or
    /// escalation-enabled solve. The default only watches for NaN/Inf and
    /// sustained divergence; stagnation detection is opt-in.
    pub watchdog: crate::robust::WatchdogConfig,
    /// Distributed shard cluster ([`crate::cluster`]): when set, dense
    /// jobs routed to the block-parallel pair (`kaczmarz_par` /
    /// `bak_par`) are sharded across the configured workers instead of
    /// across local threads — bit-identically, at equal `(seed, shards)`.
    /// Every other job (other backends, sparse/streamed matrices, and
    /// the guarded durable/escalating path) still runs in-process.
    pub cluster: Option<crate::cluster::ClusterConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: crate::parallel::default_threads(),
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            artifact_dir: None,
            max_inflight: 0,
            max_queue_wait_ms: 0,
            degraded_sweeps: None,
            journal_dir: None,
            checkpoint_every: 8,
            watchdog: crate::robust::WatchdogConfig::default(),
            cluster: None,
        }
    }
}

/// The armed cluster path, derived from [`CoordinatorConfig::cluster`]
/// once at startup and shared by every worker thread.
struct ClusterState {
    driver: Arc<crate::cluster::ClusterDriver>,
    /// Fixed shard count override; `None` uses each request's `threads`.
    shards: Option<usize>,
}

/// Durable-execution knobs, derived from [`CoordinatorConfig`] once at
/// startup and shared by every worker.
#[derive(Clone)]
struct Durability {
    journal_dir: Option<PathBuf>,
    checkpoint_every: usize,
    watchdog: crate::robust::WatchdogConfig,
}

struct Envelope {
    req: SolveRequest,
    reply: mpsc::Sender<SolveOutcome>,
    submitted: Instant,
    /// Admission permit ([`crate::robust::AdmissionGate`]); released by
    /// RAII wherever the envelope dies — reply, shed, panic or shutdown.
    permit: Option<crate::robust::Permit>,
}

struct JobEnvelope {
    job: SolveJob,
    replies: Vec<(mpsc::Sender<SolveOutcome>, Instant)>,
    /// Permits of every admitted member; dropped when the job finishes.
    permits: Vec<crate::robust::Permit>,
}

/// The running service. Dropping it shuts down cleanly.
pub struct Coordinator {
    submit_q: Arc<BoundedQueue<Envelope>>,
    metrics: Arc<Metrics>,
    traces: Arc<TraceRing>,
    engine: Option<Arc<Engine>>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    executor: Option<Arc<Executor<JobEnvelope>>>,
    gate: Option<Arc<crate::robust::AdmissionGate>>,
    max_queue_wait_ms: u64,
    degraded_sweeps: Option<usize>,
    cluster: Option<Arc<ClusterState>>,
}

impl Coordinator {
    /// Start the service: spawns the scheduler and a
    /// `config.workers`-wide [`Executor`].
    pub fn start(config: CoordinatorConfig) -> Self {
        crate::robust::faults::init_from_env();
        let metrics = Arc::new(Metrics::new());
        let traces = Arc::new(TraceRing::new(TRACE_RING_CAP));
        let engine = config.artifact_dir.as_ref().and_then(|dir| match Engine::new(dir) {
            Ok(e) => Some(Arc::new(e)),
            Err(err) => {
                emit(Level::Warn, "coordinator", format_args!(
                    "PJRT engine unavailable ({err}); native backends only"));
                None
            }
        });

        let submit_q: Arc<BoundedQueue<Envelope>> =
            Arc::new(BoundedQueue::new(config.queue_capacity));

        if let Some(dir) = &config.journal_dir {
            if let Err(err) = std::fs::create_dir_all(dir) {
                // Stay up: the checkpoint probe swallows write failures,
                // so an uncreatable journal degrades durability, not
                // availability.
                emit(Level::Warn, "coordinator", format_args!(
                    "journal dir {} not creatable ({err}); checkpoints will not persist",
                    dir.display()));
            }
        }
        let durability = Durability {
            journal_dir: config.journal_dir.clone(),
            checkpoint_every: config.checkpoint_every.max(1),
            watchdog: config.watchdog,
        };

        // Arm the cluster path: join-probe the roster now (unreachable
        // workers start dead and solves fail typed rather than hanging),
        // seed the `cluster_workers` gauge, and start the heartbeat when
        // one is configured.
        let cluster: Option<Arc<ClusterState>> = config.cluster.as_ref().map(|cfg| {
            let driver = Arc::new(crate::cluster::ClusterDriver::from_config(cfg));
            driver.attach_metrics(metrics.clone());
            emit(Level::Info, "coordinator", format_args!(
                "cluster armed: {}/{} workers alive",
                driver.membership().alive_count(),
                driver.membership().len()));
            Arc::new(ClusterState { driver, shards: cfg.shards })
        });

        // The worker pool: N workers pulling jobs from a bounded injector,
        // panic-isolated per job (a panicking solve drops its reply
        // senders — clients observe a typed Service error — and the
        // worker keeps serving).
        let executor = {
            let metrics = metrics.clone();
            let engine = engine.clone();
            let traces = traces.clone();
            let dur = durability.clone();
            let cluster = cluster.clone();
            Arc::new(Executor::start(
                "bak-worker",
                config.workers.max(1),
                config.queue_capacity,
                move |_worker, env: JobEnvelope| {
                    metrics
                        .job_queue_depth
                        .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                    // Fault injection: a panicking worker is the executor's
                    // panic-isolation path — reply senders (and permits)
                    // drop, clients observe a typed Service error.
                    crate::robust::faults::maybe_panic_worker();
                    run_job(env, engine.as_ref(), &metrics, &traces, &dur, cluster.as_deref());
                },
            ))
        };
        metrics.attach_pool(executor.stats());

        // Scheduler: drain submit queue, coalesce, feed the executor.
        let scheduler = {
            let submit_q = submit_q.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            let policy = config.batch;
            std::thread::Builder::new()
                .name("bak-scheduler".into())
                .spawn(move || {
                    while let Some(first) = submit_q.pop() {
                        if let Some(d) = crate::robust::faults::queue_stall() {
                            std::thread::sleep(d);
                        }
                        // Opportunistic coalescing window: whatever else is
                        // already queued right now.
                        let mut envs = vec![first];
                        envs.extend(submit_q.drain_now());
                        schedule_batch(envs, &policy, &executor, &metrics);
                    }
                })
                .expect("spawn scheduler")
        };

        Self {
            submit_q,
            metrics,
            traces,
            engine,
            scheduler: Some(scheduler),
            executor: Some(executor),
            gate: (config.max_inflight > 0)
                .then(|| crate::robust::AdmissionGate::new(config.max_inflight)),
            max_queue_wait_ms: config.max_queue_wait_ms,
            degraded_sweeps: config.degraded_sweeps,
            cluster,
        }
    }

    /// Submit a request; returns the reply receiver. Blocks when the
    /// submit queue is full (backpressure); errors after shutdown.
    pub fn submit(
        &self,
        req: SolveRequest,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolverError> {
        self.submit_with_permit(req, None)
    }

    fn submit_with_permit(
        &self,
        req: SolveRequest,
        permit: Option<crate::robust::Permit>,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolverError> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests_submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.submit_q
            .push(Envelope { req, reply: tx, submitted: Instant::now(), permit })
            .map_err(|_| SolverError::Service("coordinator is shut down".into()))?;
        Ok(rx)
    }

    /// Submit through the robustness layer: arms the request's deadline
    /// (when [`SolveRequest::deadline_ms`] is set — queue wait consumes
    /// budget) and passes the admission gate when one is configured.
    ///
    /// A saturated gate either sheds the request with a typed
    /// [`SolverError::Overloaded`] (carrying a `retry_after_ms` hint from
    /// the recent solve-latency mean) or — when
    /// [`CoordinatorConfig::degraded_sweeps`] is set — admits it past the
    /// gate as a reduced-sweep BAK solve flagged `degraded`.
    pub fn submit_robust(
        &self,
        mut req: SolveRequest,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolverError> {
        if let Some(ms) = req.deadline_ms {
            req.opts.cancel = crate::robust::CancelToken::with_deadline_ms(ms);
        }
        let mut permit = None;
        if let Some(gate) = &self.gate {
            let wait = std::time::Duration::from_millis(self.max_queue_wait_ms);
            permit = gate.try_acquire().or_else(|| {
                if self.max_queue_wait_ms > 0 {
                    gate.acquire_timeout(wait)
                } else {
                    None
                }
            });
            if permit.is_none() {
                match self.degraded_sweeps {
                    Some(sweeps) => {
                        // Degraded mode: answer anyway, but cheaply — the
                        // sweep budget is the solver family's natural
                        // degradation axis.
                        req.opts.max_sweeps = req.opts.max_sweeps.min(sweeps.max(1));
                        req.backend = SolverKind::Bak;
                        req.degraded = true;
                        self.metrics
                            .degraded_solves
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    None => {
                        self.metrics
                            .jobs_shed
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        return Err(SolverError::Overloaded {
                            retry_after_ms: self.retry_after_hint_ms(),
                        });
                    }
                }
            }
        }
        self.submit_with_permit(req, permit)
    }

    /// Backoff hint for shed clients: the recent mean solve latency,
    /// clamped to [25ms, 5s] so a cold (or pathological) histogram still
    /// yields a sane hint.
    fn retry_after_hint_ms(&self) -> u64 {
        ((self.metrics.solve_latency.mean() * 1e3) as u64).clamp(25, 5000)
    }

    /// Submit without blocking; Err(request) when the queue is full.
    pub fn try_submit(
        &self,
        req: SolveRequest,
    ) -> Result<mpsc::Receiver<SolveOutcome>, SolveRequest> {
        let (tx, rx) = mpsc::channel();
        match self.submit_q.try_push(Envelope {
            req,
            reply: tx,
            submitted: Instant::now(),
            permit: None,
        }) {
            Ok(()) => {
                self.metrics
                    .requests_submitted
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(rx)
            }
            Err(env) => {
                self.metrics
                    .queue_rejections
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(env.req)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn solve_blocking(&self, req: SolveRequest) -> SolveOutcome {
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| SolveOutcome {
                id: 0,
                report: Err(SolverError::Service("reply channel dropped".into())),
                backend: SolverKind::Auto,
                seconds: 0.0,
                batch_size: 0,
                telemetry: None,
                degraded: false,
                resumed: false,
                escalated_to: None,
                resharded: false,
            }),
            Err(e) => SolveOutcome {
                id: 0,
                report: Err(e),
                backend: SolverKind::Auto,
                seconds: 0.0,
                batch_size: 0,
                telemetry: None,
                degraded: false,
                resumed: false,
                escalated_to: None,
                resharded: false,
            },
        }
    }

    /// Service metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Ring of recently completed traced solves (oldest first in
    /// [`TraceRing::recent`]).
    pub fn traces(&self) -> &Arc<TraceRing> {
        &self.traces
    }

    /// The PJRT engine, when artifacts were loaded.
    pub fn engine(&self) -> Option<&Arc<Engine>> {
        self.engine.as_ref()
    }

    /// The cluster driver, when [`CoordinatorConfig::cluster`] armed one.
    pub fn cluster(&self) -> Option<&Arc<crate::cluster::ClusterDriver>> {
        self.cluster.as_ref().map(|c| &c.driver)
    }

    /// Graceful shutdown: stop intake, drain, join.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Stop intake, let the scheduler flush everything it has into the
        // executor, then drain the executor (pending jobs still run).
        self.submit_q.close();
        if let Some(s) = self.scheduler.take() {
            let _ = s.join();
        }
        if let Some(exec) = self.executor.take() {
            if let Ok(exec) = Arc::try_unwrap(exec).map_err(|_| ()) {
                exec.shutdown();
            }
            // A still-shared executor (scheduler clone already dropped by
            // the join above, so this is unreachable in practice) shuts
            // down via its Drop impl.
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn schedule_batch(
    envs: Vec<Envelope>,
    policy: &BatchPolicy,
    executor: &Executor<JobEnvelope>,
    metrics: &Metrics,
) {
    // Preserve reply channels (and admission permits) through the
    // coalescer by id.
    type ReplySlot = (mpsc::Sender<SolveOutcome>, Instant, Option<crate::robust::Permit>);
    let mut replies: std::collections::HashMap<u64, ReplySlot> = std::collections::HashMap::new();
    let mut reqs = Vec::with_capacity(envs.len());
    for env in envs {
        metrics.queue_wait.record(env.submitted.elapsed().as_secs_f64());
        // Singleton jobs: traced requests (the span timeline must describe
        // exactly one solve), deadline-armed requests (one member's budget
        // must not cancel batch-mates), degraded requests (their clamped
        // sweep budget must not infect a batch), and durable/escalating
        // requests (the journal and the watchdog's cancel token are both
        // strictly per-solve).
        let singleton = env.req.trace.is_some()
            || env.req.opts.cancel.is_enabled()
            || env.req.degraded
            || env.req.job_id.is_some()
            || env.req.escalate;
        if singleton {
            if let Some(ctx) = env.req.trace.clone() {
                // The queue wait is recorded retroactively: the span began
                // when the request was submitted.
                ctx.record_ns("queue_wait", ctx.ns_of(env.submitted), ctx.now_ns(), None);
            }
            metrics.job_queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let permits = env.permit.into_iter().collect();
            let job = SolveJob::single(env.req);
            let env = JobEnvelope { job, replies: vec![(env.reply, env.submitted)], permits };
            if executor.submit(env).is_err() {
                metrics.job_queue_depth.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                return; // shutting down
            }
            continue;
        }
        replies.insert(env.req.id, (env.reply, env.submitted, env.permit));
        reqs.push(env.req);
    }
    for job in coalesce(reqs, policy) {
        let mut job_replies = Vec::with_capacity(job.len());
        let mut permits = Vec::new();
        for (id, _) in &job.members {
            let (tx, sub, permit) = replies.remove(id).expect("reply channel per member");
            job_replies.push((tx, sub));
            permits.extend(permit);
        }
        if job.len() > 1 {
            metrics
                .batched_members
                .fetch_add(job.len() as u64, std::sync::atomic::Ordering::Relaxed);
        }
        // Gauge up BEFORE the submit so a worker's pop-side decrement can
        // never observe the queue entry ahead of the increment.
        metrics.job_queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if executor.submit(JobEnvelope { job, replies: job_replies, permits }).is_err() {
            metrics.job_queue_depth.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            return; // shutting down; remaining replies drop -> RecvError
        }
    }
}

fn run_job(
    env: JobEnvelope,
    engine: Option<&Arc<Engine>>,
    metrics: &Metrics,
    traces: &TraceRing,
    dur: &Durability,
    cluster: Option<&ClusterState>,
) {
    // `_permits` stays alive until the function returns, so the admission
    // gate frees capacity only after every reply has been sent.
    let JobEnvelope { mut job, replies, permits: _permits } = env;
    metrics.jobs_run.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // A deadline that expired while the job sat in the queue: answer every
    // member immediately with a typed error (zero-coefficient "best", unit
    // relative residual) instead of burning a worker on a doomed solve.
    if job.opts.cancel.is_cancelled() {
        let batch_size = job.len();
        metrics
            .jobs_deadline_exceeded
            .fetch_add(batch_size as u64, std::sync::atomic::Ordering::Relaxed);
        for ((id, _), (reply, _submitted)) in job.members.iter().zip(replies) {
            metrics.requests_failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let _ = reply.send(SolveOutcome {
                id: *id,
                report: Err(SolverError::DeadlineExceeded {
                    best: vec![0.0; job.x.cols()],
                    rel_residual: 1.0,
                    sweeps: 0,
                }),
                backend: job.backend,
                seconds: 0.0,
                batch_size,
                telemetry: None,
                degraded: job.degraded,
                resumed: false,
                escalated_to: None,
                resharded: false,
            });
        }
        return;
    }
    // Traced job: mint a probe into the options so the solver loop feeds
    // the trajectory ring, and open per-stage spans around route / solve /
    // merge below. Untraced jobs skip all of it (probe stays disabled).
    let tracing: Option<(Arc<TraceCtx>, Arc<RingProbe>)> = job.trace.clone().map(|ctx| {
        let probe = RingProbe::new(TRACE_TRAJECTORY_CAP);
        // Fold an already-attached probe (a caller's, or — on guarded jobs
        // below — soon the checkpoint/watchdog members) into a fan-out
        // instead of silently replacing it.
        job.opts.probe = match job.opts.probe.inner() {
            Some(existing) => ProbeHandle::new(MultiProbe::new(vec![existing, probe.clone()])),
            None => ProbeHandle::new(probe.clone()),
        };
        (ctx, probe)
    });
    let route_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("route", None));
    let decision = route(
        job.backend,
        job.x.rows(),
        job.x.cols(),
        job.x.is_sparse(),
        job.x.is_streamed(),
        job.opts.threads,
        engine.map(|e| e.manifest()),
    );
    if let (Some((ctx, _)), Some(idx)) = (&tracing, route_span) {
        ctx.end(idx);
    }
    metrics.record_backend_job(decision.backend);
    let batch_size = job.len();
    let solve_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("solve", None));
    let trace_arg: Option<(&TraceCtx, usize)> = match (&tracing, solve_span) {
        (Some((ctx, _)), Some(idx)) => Some((ctx.as_ref(), idx)),
        _ => None,
    };
    // Durable (`job_id`) and self-healing (`escalate`) requests take the
    // guarded path: always singleton (the scheduler guarantees it), with
    // checkpoint + watchdog probes folded in around the solve.
    let guarded = job.len() == 1 && (job.job_id.is_some() || job.escalate);
    // Cluster interception: dense jobs on the block-parallel pair go out
    // over the wire instead of across local threads. Guarded jobs stay
    // in-process — the checkpoint/watchdog probes hook the local solver
    // loop, which a remote shard sweep has no access to.
    let clustered = !guarded
        && matches!(decision.backend, SolverKind::KaczmarzPar | SolverKind::BakPar)
        && matches!(&job.x, SharedMatrix::Dense(_));
    let outcomes = if guarded {
        vec![run_guarded(&job, decision.backend, engine, metrics, dur)]
    } else if let (true, Some(cl), SharedMatrix::Dense(x)) = (clustered, cluster, &job.x) {
        execute_cluster_job(cl, &job, x, decision.backend, trace_arg)
    } else {
        execute_job(&job, decision.backend, engine, metrics, trace_arg)
    };
    if let (Some((ctx, _)), Some(idx)) = (&tracing, solve_span) {
        ctx.end(idx);
    }

    // Merge stage: attribute latencies and stitch ids back on.
    let merge_span = tracing.as_ref().map(|(ctx, _)| ctx.begin("merge", None));
    let mut merged = Vec::with_capacity(outcomes.len());
    for ((id, _), mut outcome) in job.members.iter().zip(outcomes) {
        // A solve whose residual went non-finite stopped on Breakdown;
        // surface it as the typed NumericalBreakdown error. (Guarded jobs
        // already converted — their watchdog carries the detail — so this
        // only catches breakdowns on the plain path.)
        if matches!(&outcome.report, Ok(rep) if rep.stop == solver::StopReason::Breakdown) {
            if let Ok(rep) = std::mem::replace(
                &mut outcome.report,
                Err(SolverError::Service(String::new())),
            ) {
                outcome.report = Err(SolverError::NumericalBreakdown {
                    detail: "residual became non-finite".into(),
                    sweeps: rep.sweeps,
                });
            }
        }
        // A deadline-armed solve that stopped on Cancelled surfaces as the
        // typed DeadlineExceeded error, carrying the best-so-far solution
        // (the solver's exit invariant guarantees `e == y - Xa` for it).
        if job.opts.cancel.is_enabled()
            && matches!(&outcome.report, Ok(rep) if rep.stop == solver::StopReason::Cancelled)
        {
            if let Ok(rep) = std::mem::replace(
                &mut outcome.report,
                Err(SolverError::Service(String::new())),
            ) {
                metrics
                    .jobs_deadline_exceeded
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let rel_residual = rep.rel_residual();
                let sweeps = rep.sweeps;
                outcome.report = Err(SolverError::DeadlineExceeded {
                    best: rep.a,
                    rel_residual,
                    sweeps,
                });
            }
        }
        if matches!(&outcome.report, Err(SolverError::CorruptData { .. })) {
            metrics.corrupt_chunks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        let ok = outcome.report.is_ok();
        metrics.solve_latency.record(outcome.seconds);
        if ok {
            metrics.requests_completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        } else {
            metrics.requests_failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        merged.push(SolveOutcome { id: *id, batch_size, degraded: job.degraded, ..outcome });
    }
    if let (Some((ctx, _)), Some(idx)) = (&tracing, merge_span) {
        ctx.end(idx);
    }

    // Assemble the telemetry AFTER every span closed so the snapshot is
    // complete, keep a copy in the service-wide ring, and attach it to the
    // (singleton) traced outcome.
    let telemetry = tracing.map(|(ctx, probe)| {
        let tel = Telemetry {
            trace_id: ctx.id(),
            spans: ctx.spans(),
            trajectory: probe.snapshot(),
        };
        traces.push(tel.clone());
        tel
    });
    if let Some(t) = &telemetry {
        emit_traced(
            Level::Debug,
            "coordinator",
            Some(t.trace_id),
            format_args!(
                "traced solve on '{}': {} spans, {} trajectory points",
                decision.backend,
                t.spans.len(),
                t.trajectory.len()
            ),
        );
    }
    for (mut outcome, (reply, _submitted)) in merged.into_iter().zip(replies) {
        if let Some(t) = &telemetry {
            outcome.telemetry = Some(t.clone());
        }
        let _ = reply.send(outcome);
    }
}

/// The backend escalation ladder: cheapest first, most robust last. A
/// breakdown on one rung retries on the rungs above it — coordinate
/// descent's conditioning sensitivity hands off to CGLS (normal-equation
/// Krylov, better conditioned per iteration), then to Householder QR,
/// which is direct and cannot diverge.
const ESCALATION_LADDER: [SolverKind; 3] = [SolverKind::Bak, SolverKind::Cgls, SolverKind::Qr];

/// The rungs above `from`. Off-ladder kinds (the BAK/Kaczmarz variants)
/// start above BAK: retrying the same iteration family against the same
/// conditioning would break down the same way.
fn escalation_ladder(from: SolverKind) -> &'static [SolverKind] {
    let next = ESCALATION_LADDER.iter().position(|&k| k == from).map_or(1, |i| i + 1);
    &ESCALATION_LADDER[next.min(ESCALATION_LADDER.len())..]
}

/// Journal file name for a job id: a sanitised, length-capped stem for
/// humans plus the CRC32 of the *full* id so distinct ids never collide
/// (and path metacharacters never escape the journal directory).
fn journal_file_name(job_id: &str) -> String {
    let stem: String = job_id
        .chars()
        .take(64)
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                c
            } else {
                '_'
            }
        })
        .collect();
    format!("{stem}-{:08x}.ckpt", crate::util::crc32::crc32(job_id.as_bytes()))
}

/// Execute a singleton durable/escalating job: resume from the journal
/// when a compatible checkpoint exists, checkpoint the iterate as it
/// runs, watch its numerical health, and — when asked — climb the
/// backend ladder on breakdown instead of failing.
fn run_guarded(
    job: &SolveJob,
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
    metrics: &Metrics,
    dur: &Durability,
) -> SolveOutcome {
    use std::sync::atomic::Ordering::Relaxed;
    let t0 = Instant::now();
    let y = &job.members[0].1;
    let mut opts = job.opts.clone();

    // Probe fan-out, preserving whatever is already attached (a caller's
    // probe, or the tracing RingProbe minted by `run_job`).
    let mut probes: Vec<Arc<dyn SolveProbe>> = opts.probe.inner().into_iter().collect();

    // Durable journal: resume from a compatible checkpoint — same id,
    // same solver, same seed, same shape — then keep checkpointing.
    // Incompatible or unreadable (CRC-rejected) checkpoints are ignored:
    // a cold start is always a safe answer.
    let ckpt_path = match (&dur.journal_dir, &job.job_id) {
        (Some(dir), Some(id)) => Some(dir.join(journal_file_name(id))),
        _ => None,
    };
    let warm = match (&ckpt_path, &job.job_id) {
        (Some(path), Some(id)) => Checkpoint::load(path).ok().filter(|c| {
            c.job_id == *id
                && c.solver == backend.as_str()
                && c.seed == opts.seed
                && c.a.len() == job.x.cols()
                && c.e.len() == y.len()
        }),
        _ => None,
    };
    let resumed = warm.is_some();
    if resumed {
        metrics.resumes.fetch_add(1, Relaxed);
    }
    let ckpt_probe = match (&ckpt_path, &job.job_id) {
        (Some(path), Some(id)) => {
            let p = CheckpointProbe::new(
                path.clone(),
                id.clone(),
                backend.as_str(),
                opts.seed,
                dur.checkpoint_every,
            );
            probes.push(p.clone());
            Some(p)
        }
        _ => None,
    };

    // Health watchdog. When the job already carries an armed deadline
    // token the watchdog guards that same token (one token serves both;
    // `tripped()` disambiguates afterwards, and `job.opts.cancel` stays
    // untouched so the merge loop still attributes genuine deadline hits
    // correctly). Otherwise it gets its own.
    let wd = Watchdog::guarding(
        dur.watchdog,
        if opts.cancel.is_enabled() { opts.cancel.clone() } else { CancelToken::manual() },
    );
    opts.cancel = wd.cancel_token();
    probes.push(wd.probe());
    opts.probe = ProbeHandle::new(MultiProbe::new(probes));

    let mut report = guarded_solve(job, y, backend, engine, warm.as_ref(), &opts);

    // Fold watchdog trips and non-finite exits into the typed breakdown.
    let mut verdict: Option<SolverError> = if wd.tripped() {
        wd.verdict().to_error()
    } else {
        match &report {
            Ok(rep) if rep.stop == solver::StopReason::Breakdown => {
                Some(SolverError::NumericalBreakdown {
                    detail: "residual became non-finite".into(),
                    sweeps: rep.sweeps,
                })
            }
            _ => None,
        }
    };

    let mut escalated_to = None;
    if verdict.is_some() && job.escalate {
        for &kind in escalation_ladder(backend) {
            metrics.escalations.fetch_add(1, Relaxed);
            // Each rung gets a fresh watchdog with its own token: a trip
            // on the rung below must not pre-cancel this attempt, and a
            // job deadline token that already fired would make every rung
            // a no-op anyway.
            let esc_wd = Watchdog::new(dur.watchdog);
            let mut esc_opts = job.opts.clone();
            esc_opts.cancel = esc_wd.cancel_token();
            let mut esc_probes: Vec<Arc<dyn SolveProbe>> =
                job.opts.probe.inner().into_iter().collect();
            esc_probes.push(esc_wd.probe());
            esc_opts.probe = ProbeHandle::new(MultiProbe::new(esc_probes));
            match guarded_solve(job, y, kind, engine, None, &esc_opts) {
                Ok(rep)
                    if !esc_wd.tripped()
                        && rep.stop != solver::StopReason::Breakdown
                        && rep.a.iter().all(|v| v.is_finite()) =>
                {
                    metrics.record_backend_job(kind);
                    emit(
                        Level::Warn,
                        "coordinator",
                        format_args!(
                            "numerical breakdown on '{backend}'; escalated to '{kind}'"
                        ),
                    );
                    escalated_to = Some(kind);
                    report = Ok(rep);
                    verdict = None;
                    break;
                }
                Ok(_) => {
                    // This rung broke down too; carry its (fresher)
                    // verdict up and keep climbing.
                    if let Some(err) = esc_wd.verdict().to_error() {
                        verdict = Some(err);
                    }
                }
                Err(_) => {
                    // Rung unavailable for this matrix shape (e.g. QR on
                    // a streamed job); try the next one.
                }
            }
        }
    }
    if let Some(err) = verdict {
        report = Err(err);
    }

    // A deadline hit mid-solve: persist the best-so-far state so a retry
    // under the same job_id resumes instead of restarting. (The solver's
    // exit invariant guarantees `e == y - Xa` even on Cancelled.)
    if let (Some(path), Some(id)) = (&ckpt_path, &job.job_id) {
        if let Ok(rep) = &report {
            if rep.stop == solver::StopReason::Cancelled && !wd.tripped() {
                let ck = Checkpoint {
                    job_id: id.clone(),
                    solver: backend.as_str().to_string(),
                    sweeps: rep.sweeps as u64,
                    seed: job.opts.seed,
                    a: rep.a.clone(),
                    e: rep.e.clone(),
                };
                if ck.save_atomic(path).is_ok() {
                    metrics.checkpoints_written.fetch_add(1, Relaxed);
                }
            }
        }
    }
    if let Some(p) = &ckpt_probe {
        metrics.checkpoints_written.fetch_add(p.written(), Relaxed);
    }
    // A finished solve's journal entry is spent — delete it so a reused
    // job id starts cold. Failed or deadline-cut solves keep theirs so
    // the retry resumes.
    if let Some(path) = &ckpt_path {
        if matches!(&report, Ok(rep) if rep.stop != solver::StopReason::Cancelled) {
            let _ = std::fs::remove_file(path);
        }
    }

    SolveOutcome {
        id: 0,
        report,
        backend: escalated_to.unwrap_or(backend),
        seconds: t0.elapsed().as_secs_f64(),
        batch_size: 0,
        telemetry: None,
        degraded: job.degraded,
        resumed,
        escalated_to,
        resharded: false,
    }
}

/// One solve on the guarded path: build the problem for the job's matrix
/// representation, splice in the warm state when resuming, and dispatch
/// through the api registry (so the warm-start-aware backend adapters
/// run, not the batch-amortised paths).
fn guarded_solve(
    job: &SolveJob,
    y: &[f32],
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
    warm: Option<&Checkpoint>,
    opts: &solver::SolveOptions,
) -> Result<SolveReport, SolverError> {
    let p = match &job.x {
        SharedMatrix::Dense(x) => {
            Problem::validate_matrix(x)?;
            Problem::prevalidated(x, y)?
        }
        SharedMatrix::SparseCsc(s) => {
            Problem::validate_sparse_matrix(s)?;
            Problem::prevalidated_sparse(s, y)?
        }
        SharedMatrix::Streamed(s) => Problem::new_streamed(s, y)?,
    };
    let p = match warm {
        Some(c) => p.with_warm_state(&c.a, &c.e)?,
        None => p,
    };
    match backend {
        SolverKind::Pjrt => {
            let pjrt = match engine {
                Some(eng) => PjrtSolver::with_engine(eng.clone()),
                None => PjrtSolver::detached(),
            };
            pjrt.solve(&p, opts)
        }
        kind => match solver_for(kind) {
            Some(s) => s.solve(&p, opts),
            None => Err(SolverError::Unavailable {
                backend: kind.to_string(),
                reason: "routing pseudo-kind; not directly executable".into(),
            }),
        },
    }
}

/// Execute all members of a job on the routed backend, dispatching on the
/// matrix representation first: sparse jobs run natively on backends whose
/// `supports_sparse` capability is set; for every other backend the matrix
/// is densified once per job (logged + counted in `densified_jobs`) and
/// the dense path below takes over. Streamed (file-backed) jobs run the
/// chunk-pass solvers for the streaming trio and are never densified —
/// non-streaming backends return a typed error instead.
fn execute_job(
    job: &SolveJob,
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
    metrics: &Metrics,
    trace: Option<(&TraceCtx, usize)>,
) -> Vec<SolveOutcome> {
    match &job.x {
        SharedMatrix::Dense(x) => {
            // The batcher shares one matrix across the whole job: scan it
            // once here, before any factorization work, and only check
            // each member's (cheap) y side below.
            if let Err(e) = Problem::validate_matrix(x) {
                return per_member(job, backend, |_| Err(e.clone()));
            }
            execute_dense_job(job, x, backend, engine)
        }
        SharedMatrix::SparseCsc(s) => {
            if let Err(e) = Problem::validate_sparse_matrix(s) {
                return per_member(job, backend, |_| Err(e.clone()));
            }
            let native = backend.capabilities().is_some_and(|c| c.supports_sparse);
            if native {
                match backend {
                    // Amortise shared per-matrix work across the batch,
                    // mirroring the dense paths below: BAK computes the
                    // O(nnz) column norms once per job...
                    SolverKind::Bak => {
                        let cninv = crate::sparse::solve::colnorms_inv_csc(s);
                        per_member(job, backend, |y| {
                            Problem::prevalidated_sparse(s, y)?;
                            let mut a = vec![0.0f32; s.cols()];
                            let mut e = y.to_vec();
                            Ok(crate::sparse::solve::solve_bak_csc_warm(
                                s, &cninv, &mut a, &mut e, y, &job.opts,
                            ))
                        })
                    }
                    // ...and Kaczmarz transposes CSC->CSR once per job.
                    SolverKind::Kaczmarz => {
                        let csr = s.to_csr();
                        per_member(job, backend, |y| {
                            Problem::prevalidated_sparse(s, y)?;
                            Ok(crate::sparse::solve::solve_kaczmarz_csr(&csr, y, &job.opts))
                        })
                    }
                    _ => match solver_for(backend) {
                        Some(solver) => per_member(job, backend, |y| {
                            let p = Problem::prevalidated_sparse(s, y)?;
                            solver.solve(&p, &job.opts)
                        }),
                        None => per_member(job, backend, |_| {
                            Err(SolverError::Unavailable {
                                backend: backend.to_string(),
                                reason: "routing pseudo-kind; not directly executable".into(),
                            })
                        }),
                    },
                }
            } else {
                metrics.densified_jobs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                emit(
                    Level::Warn,
                    "coordinator",
                    format_args!(
                        "backend '{backend}' has no native sparse path; densifying {}x{} \
                         (nnz={}) for a {}-member job",
                        s.rows(),
                        s.cols(),
                        s.nnz(),
                        job.len()
                    ),
                );
                let densify_span = trace.map(|(ctx, parent)| ctx.begin("densify", Some(parent)));
                let dense = s.to_dense();
                if let (Some((ctx, _)), Some(idx)) = (trace, densify_span) {
                    ctx.end(idx);
                }
                execute_dense_job(job, &dense, backend, engine)
            }
        }
        SharedMatrix::Streamed(s) => {
            // File-backed jobs never materialise X in RAM: the streaming
            // trio consumes sequential chunk passes (recording the
            // read/stall counters), and every other backend returns its
            // typed refusal from the backends layer instead of OOMing.
            let record = |st: &crate::stream::StreamStatsSnapshot| {
                use std::sync::atomic::Ordering::Relaxed;
                metrics.stream_chunks_read.fetch_add(st.chunks_read, Relaxed);
                metrics.stream_bytes_read.fetch_add(st.bytes_read, Relaxed);
                metrics.stream_buffer_stalls.fetch_add(st.buffer_stalls, Relaxed);
            };
            // Streamed solves interleave disk reads with compute, so the
            // `stream_io` child span covers the whole chunk-pass solve —
            // it marks the phase whose wall time includes IO, not an
            // isolated IO measurement (the stall *count* is in metrics).
            let io_spanned = |f: &mut dyn FnMut() -> Result<SolveReport, SolverError>| {
                let io_span = trace.map(|(ctx, parent)| ctx.begin("stream_io", Some(parent)));
                let r = f();
                if let (Some((ctx, _)), Some(idx)) = (trace, io_span) {
                    ctx.end(idx);
                }
                r
            };
            match backend {
                SolverKind::Bak => per_member(job, backend, |y| {
                    io_spanned(&mut || {
                        let r = crate::stream::solve_bak_stream(s, y, &job.opts)?;
                        record(&r.stats);
                        Ok(r.report)
                    })
                }),
                SolverKind::Kaczmarz => per_member(job, backend, |y| {
                    io_spanned(&mut || {
                        let r = crate::stream::solve_kaczmarz_stream(s, y, &job.opts)?;
                        record(&r.stats);
                        Ok(r.report)
                    })
                }),
                SolverKind::BakMulti => {
                    // Every valid member in ONE set of chunk passes
                    // (mirrors the dense multi path); invalid members get
                    // their own error without demoting the batch.
                    let t0 = Instant::now();
                    let checks: Vec<Result<(), SolverError>> = job
                        .members
                        .iter()
                        .map(|(_, y)| Problem::new_streamed(s, y).map(|_| ()))
                        .collect();
                    let ys: Vec<Vec<f32>> = job
                        .members
                        .iter()
                        .zip(&checks)
                        .filter(|(_, c)| c.is_ok())
                        .map(|((_, y), _)| y.clone())
                        .collect();
                    let io_span =
                        trace.map(|(ctx, parent)| ctx.begin("stream_io", Some(parent)));
                    let multi_res = crate::stream::solve_bak_multi_stream(s, &ys, &job.opts);
                    if let (Some((ctx, _)), Some(idx)) = (trace, io_span) {
                        ctx.end(idx);
                    }
                    match multi_res {
                        Ok(multi) => {
                            record(&multi.stats);
                            let mut reports = multi.reports.into_iter();
                            let secs =
                                t0.elapsed().as_secs_f64() / job.len().max(1) as f64;
                            checks
                                .into_iter()
                                .map(|c| SolveOutcome {
                                    id: 0,
                                    report: c.map(|()| {
                                        reports
                                            .next()
                                            .expect("one report per valid member")
                                    }),
                                    backend,
                                    seconds: secs,
                                    batch_size: 0,
                                    telemetry: None,
                                    degraded: job.degraded,
                                    resumed: false,
                                    escalated_to: None,
                                    resharded: false,
                                })
                                .collect()
                        }
                        Err(e) => per_member(job, backend, |_| Err(e.clone())),
                    }
                }
                _ => match solver_for(backend) {
                    Some(solver) => per_member(job, backend, |y| {
                        let p = Problem::new_streamed(s, y)?;
                        solver.solve(&p, &job.opts)
                    }),
                    None => per_member(job, backend, |_| {
                        Err(SolverError::Unavailable {
                            backend: backend.to_string(),
                            reason: "routing pseudo-kind; not directly executable".into(),
                        })
                    }),
                },
            }
        }
    }
}

/// The dense execution paths, amortising shared work across the batch
/// where the backend allows it (QR factors once per job, BAK shares column
/// norms, BAK-multi walks the matrix once for every right-hand side); all
/// other registered kinds run member-by-member through the [`crate::api`]
/// registry.
fn execute_dense_job(
    job: &SolveJob,
    x: &Mat,
    backend: SolverKind,
    engine: Option<&Arc<Engine>>,
) -> Vec<SolveOutcome> {
    match backend {
        SolverKind::Qr => {
            // Factor ONCE for the whole batch (tall only; wide falls back
            // to per-member lstsq which handles min-norm internally).
            if x.rows() >= x.cols() {
                let t0 = Instant::now();
                let (f, taus) = qr::householder_qr(x);
                let factor_s = t0.elapsed().as_secs_f64() / job.len() as f64;
                job.members
                    .iter()
                    .map(|(_, y)| {
                        let t1 = Instant::now();
                        let report = qr_member_solve(x, &f, &taus, y);
                        SolveOutcome {
                            id: 0,
                            report,
                            backend,
                            seconds: factor_s + t1.elapsed().as_secs_f64(),
                            batch_size: 0,
                            telemetry: None,
                            degraded: job.degraded,
                            resumed: false,
                            escalated_to: None,
                            resharded: false,
                        }
                    })
                    .collect()
            } else {
                per_member(job, backend, |y| {
                    Problem::prevalidated(x, y)?;
                    let a = qr::lstsq_qr(x, y)?;
                    Ok(report_from_coefficients(x, y, a))
                })
            }
        }
        SolverKind::Bak => {
            let cninv = solver::colnorms_inv(x);
            per_member(job, backend, |y| {
                Problem::prevalidated(x, y)?;
                let mut a = vec![0.0f32; x.cols()];
                let mut e = y.to_vec();
                Ok(solver::bak::solve_bak_warm(x, &cninv, &mut a, &mut e, y, &job.opts))
            })
        }
        SolverKind::BakMulti => {
            // Every valid member in ONE matrix walk (chunked across
            // threads when the request asks for them — the column-norm
            // precompute is still shared); invalid members get their own
            // error without demoting the rest of the batch.
            let t0 = Instant::now();
            let checks: Vec<Result<(), SolverError>> = job
                .members
                .iter()
                .map(|(_, y)| Problem::prevalidated(x, y).map(|_| ()))
                .collect();
            let ys: Vec<Vec<f32>> = job
                .members
                .iter()
                .zip(&checks)
                .filter(|(_, c)| c.is_ok())
                .map(|((_, y), _)| y.clone())
                .collect();
            let reports = if job.opts.threads > 1 {
                crate::parallel::solve_bak_multi_par(x, &ys, &job.opts)
            } else {
                solver::solve_bak_multi(x, &ys, &job.opts)
            };
            let mut reports = reports.into_iter();
            let secs = t0.elapsed().as_secs_f64() / job.len().max(1) as f64;
            checks
                .into_iter()
                .map(|c| SolveOutcome {
                    id: 0,
                    report: c
                        .map(|()| reports.next().expect("one report per valid member")),
                    backend,
                    seconds: secs,
                    batch_size: 0,
                    telemetry: None,
                    degraded: job.degraded,
                    resumed: false,
                    escalated_to: None,
                    resharded: false,
                })
                .collect()
        }
        SolverKind::Pjrt => {
            // Reuse the api adapter: detached -> typed Unavailable, with
            // an engine -> artifact execution. One error contract.
            let pjrt = match engine {
                Some(eng) => PjrtSolver::with_engine(eng.clone()),
                None => PjrtSolver::detached(),
            };
            per_member(job, backend, |y| {
                let p = Problem::prevalidated(x, y)?;
                pjrt.solve(&p, &job.opts)
            })
        }
        SolverKind::Auto => unreachable!("router always resolves Auto"),
        kind => match solver_for(kind) {
            // Everything else (bakp, kaczmarz, gauss_southwell, cholesky,
            // gauss, cgls) dispatches through the registry.
            Some(s) => per_member(job, kind, |y| {
                let p = Problem::prevalidated(x, y)?;
                s.solve(&p, &job.opts)
            }),
            None => per_member(job, kind, |_| {
                Err(SolverError::Unavailable {
                    backend: kind.to_string(),
                    reason: "routing pseudo-kind; not directly executable".into(),
                })
            }),
        },
    }
}

/// Execute a dense block-parallel job over the cluster, member by member
/// (the shard caches on the workers are shared across members of the same
/// job matrix only through the per-round `(job, shard)` key — each solve
/// is its own driver job). The shard count plays `threads`' role: a
/// config override pins it, otherwise the request's `threads` knob
/// carries over so the result stays bit-identical to the in-process
/// solver the router would have run.
fn execute_cluster_job(
    cl: &ClusterState,
    job: &SolveJob,
    x: &Mat,
    backend: SolverKind,
    trace: Option<(&TraceCtx, usize)>,
) -> Vec<SolveOutcome> {
    if let Err(e) = Problem::validate_matrix(x) {
        return per_member(job, backend, |_| Err(e.clone()));
    }
    let mut opts = job.opts.clone();
    if let Some(shards) = cl.shards {
        opts.threads = shards.max(1);
    }
    job.members
        .iter()
        .map(|(_, y)| {
            let t0 = Instant::now();
            let (report, resharded) = match Problem::prevalidated(x, y)
                .and_then(|_| cl.driver.solve(backend, x, y, &opts, trace))
            {
                Ok(out) => (Ok(out.report), out.resharded),
                Err(e) => (Err(e), false),
            };
            SolveOutcome {
                id: 0,
                report,
                backend,
                seconds: t0.elapsed().as_secs_f64(),
                batch_size: 0,
                telemetry: None,
                degraded: job.degraded,
                resumed: false,
                escalated_to: None,
                resharded,
            }
        })
        .collect()
}

fn per_member(
    job: &SolveJob,
    backend: SolverKind,
    mut f: impl FnMut(&[f32]) -> Result<SolveReport, SolverError>,
) -> Vec<SolveOutcome> {
    job.members
        .iter()
        .map(|(_, y)| {
            let t0 = Instant::now();
            let report = f(y);
            SolveOutcome {
                id: 0,
                report,
                backend,
                seconds: t0.elapsed().as_secs_f64(),
                batch_size: 0,
                telemetry: None,
                degraded: job.degraded,
                resumed: false,
                escalated_to: None,
                resharded: false,
            }
        })
        .collect()
}

fn qr_member_solve(
    x: &Mat,
    f: &Mat,
    taus: &[f32],
    y: &[f32],
) -> Result<SolveReport, SolverError> {
    Problem::prevalidated(x, y)?;
    let qty = qr::apply_qt(f, taus, y);
    let a = qr::solve_upper_triangular(f, &qty)?;
    Ok(report_from_coefficients(x, y, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn planted(seed: u64, obs: usize, vars: usize) -> (Arc<Mat>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        (Arc::new(x), y, a)
    }

    #[test]
    fn solve_roundtrip_native_bak() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(400, 600, 30);
        let mut req = SolveRequest::new(1, x, y);
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        let rep = out.report.expect("solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        assert_eq!(out.backend, SolverKind::Bak);
        coord.shutdown();
    }

    #[test]
    fn auto_routes_square_to_qr() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(401, 50, 50);
        let out = coord.solve_blocking(SolveRequest::new(2, x, y));
        assert_eq!(out.backend, SolverKind::Qr);
        let rep = out.report.unwrap();
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-2);
        coord.shutdown();
    }

    #[test]
    fn batched_same_matrix_requests_all_answered() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        });
        let (x, _, _) = planted(402, 300, 20);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let mut rng = Rng::seed(500 + i);
            let a: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
            let y = x.matvec(&a);
            let mut req = SolveRequest::new(i, x.clone(), y);
            req.backend = SolverKind::Qr;
            rxs.push((i, a, coord.submit(req).unwrap()));
        }
        for (i, a_true, rx) in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, i);
            let rep = out.report.unwrap();
            assert!(
                crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3,
                "member {i}"
            );
        }
        coord.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(403, 20, 5);
        coord.shutdown();
        // Start a fresh one to prove restartability, then check closed
        // submit path via a second coordinator's lifecycle.
        let coord2 = Coordinator::start(CoordinatorConfig::default());
        let out = coord2.solve_blocking(SolveRequest::new(9, x, y));
        assert!(out.report.is_ok());
    }

    #[test]
    fn metrics_accumulate() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(404, 100, 10);
        let _ = coord.solve_blocking(SolveRequest::new(1, x.clone(), y.clone()));
        let _ = coord.solve_blocking(SolveRequest::new(2, x, y));
        let m = coord.metrics();
        assert_eq!(m.requests_submitted.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(m.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert!(m.solve_latency.count() >= 2);
        coord.shutdown();
    }

    #[test]
    fn explicit_bakp_backend() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(405, 500, 40);
        let mut req = SolveRequest::new(3, x, y);
        req.backend = SolverKind::Bakp;
        req.opts = solver::SolveOptions::accurate();
        req.opts.thr = 8;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Bakp);
        let rep = out.report.unwrap();
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        coord.shutdown();
    }

    #[test]
    fn pjrt_without_engine_fails_cleanly() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(406, 100, 10);
        let mut req = SolveRequest::new(4, x, y);
        req.backend = SolverKind::Pjrt;
        let out = coord.solve_blocking(req);
        // Router falls back to Bakp when no engine manifest exists.
        assert_eq!(out.backend, SolverKind::Bakp);
        assert!(out.report.is_ok());
        coord.shutdown();
    }

    fn planted_sparse(
        seed: u64,
        obs: usize,
        vars: usize,
        density: f64,
    ) -> (Arc<crate::sparse::CscMat>, Vec<f32>, Vec<f32>) {
        let w = crate::bench::workload::SparseWorkload::uniform(
            crate::bench::workload::WorkloadSpec::new(obs, vars, seed),
            density,
        );
        (Arc::new(w.x), w.y, w.a_true)
    }

    #[test]
    fn sparse_auto_runs_natively_without_densification() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_sparse(407, 300, 24, 0.1);
        let mut req = SolveRequest::builder(1, x, y).build();
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        // Auto + sparse routes to a sparse-native solver...
        assert!(matches!(out.backend, SolverKind::Bak | SolverKind::Bakp));
        let rep = out.report.expect("sparse solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        // ...so nothing was densified, and the backend job was counted.
        let m = coord.metrics();
        assert_eq!(m.densified_jobs.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(m.backend_jobs(out.backend), 1);
        coord.shutdown();
    }

    #[test]
    fn sparse_request_on_dense_only_backend_densifies_and_counts() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_sparse(408, 120, 16, 0.15);
        let mut req = SolveRequest::builder(2, x, y).build();
        req.backend = SolverKind::Qr;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Qr);
        let rep = out.report.expect("densified qr solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        let m = coord.metrics();
        assert_eq!(m.densified_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(m.backend_jobs(SolverKind::Qr), 1);
        coord.shutdown();
    }

    #[test]
    fn sparse_requests_batch_and_all_answer() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        });
        let (x, _, _) = planted_sparse(409, 200, 12, 0.2);
        let mut rng = Rng::seed(410);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let a: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let y = x.matvec(&a);
            let mut req = SolveRequest::builder(i, x.clone(), y).build();
            req.backend = SolverKind::Cgls;
            req.opts = solver::SolveOptions::accurate();
            rxs.push((i, a, coord.submit(req).unwrap()));
        }
        for (i, a_true, rx) in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, i);
            let rep = out.report.expect("sparse cgls ok");
            assert!(
                crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-2,
                "member {i}"
            );
        }
        assert_eq!(
            coord.metrics().densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        coord.shutdown();
    }

    #[test]
    fn queue_depth_returns_to_zero_when_drained() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(411, 80, 8);
        let _ = coord.solve_blocking(SolveRequest::new(1, x, y));
        assert_eq!(
            coord.metrics().job_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        coord.shutdown();
    }

    #[test]
    fn auto_with_threads_routes_to_bak_par() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(412, 4000, 16);
        let mut req = SolveRequest::new(1, x, y);
        req.opts = solver::SolveOptions::accurate();
        req.opts.threads = 4;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::BakPar);
        let rep = out.report.expect("threaded solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        coord.shutdown();
    }

    #[test]
    fn explicit_kaczmarz_par_backend_over_service() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted(413, 480, 20);
        let mut req = SolveRequest::new(2, x, y);
        req.backend = SolverKind::KaczmarzPar;
        req.opts = solver::SolveOptions::builder()
            .max_sweeps(2000)
            .tol(1e-4)
            .threads(2)
            .build();
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::KaczmarzPar);
        let rep = out.report.expect("kaczmarz_par ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 0.05);
        coord.shutdown();
    }

    #[test]
    fn multi_member_sparse_job_densifies_once() {
        // The satellite contract: one warning/count per JOB, not per
        // member. Drive execute_job directly so the batch composition is
        // deterministic.
        let (x, _, _) = planted_sparse(414, 80, 10, 0.2);
        let mut rng = Rng::seed(415);
        let members: Vec<(u64, Vec<f32>)> = (0..5u64)
            .map(|i| {
                let a: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
                (i, x.matvec(&a))
            })
            .collect();
        let job = super::super::request::SolveJob {
            x: super::super::request::SharedMatrix::SparseCsc(x),
            members,
            opts: solver::SolveOptions::default(),
            backend: SolverKind::Qr,
            trace: None,
            degraded: false,
            job_id: None,
            escalate: false,
        };
        let metrics = Metrics::new();
        let outcomes = execute_job(&job, SolverKind::Qr, None, &metrics, None);
        assert_eq!(outcomes.len(), 5);
        assert!(outcomes.iter().all(|o| o.report.is_ok()));
        assert_eq!(
            metrics.densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "densification counted once for the whole job"
        );
    }

    fn planted_streamed(
        seed: u64,
        obs: usize,
        vars: usize,
        chunk: usize,
        tag: &str,
    ) -> (Arc<crate::stream::StreamedMatrix>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a);
        let path = crate::stream::temp_chunk_path(tag);
        crate::stream::write_chunked_dense(&x, chunk, &path).expect("write chunked");
        let s = crate::stream::StreamedMatrix::open(&path).expect("open chunked");
        (Arc::new(s), y, a)
    }

    #[test]
    fn streamed_auto_routes_to_bak_and_counts_stream_metrics() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, a_true) = planted_streamed(420, 600, 30, 7, "svc_auto");
        let path = x.path().to_path_buf();
        let mut req = SolveRequest::builder(1, x, y).build();
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Bak);
        let rep = out.report.expect("streamed solve ok");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        let m = coord.metrics();
        use std::sync::atomic::Ordering::Relaxed;
        assert!(m.stream_chunks_read.load(Relaxed) > 0);
        assert!(m.stream_bytes_read.load(Relaxed) > 0);
        assert_eq!(m.densified_jobs.load(Relaxed), 0);
        coord.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_job_on_non_streaming_backend_gets_typed_error() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted_streamed(421, 120, 10, 4, "svc_refuse");
        let path = x.path().to_path_buf();
        let mut req = SolveRequest::builder(2, x, y).build();
        req.backend = SolverKind::Qr;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Qr, "hint honoured through routing");
        match out.report {
            Err(SolverError::Unavailable { backend, .. }) => assert_eq!(backend, "qr"),
            other => panic!("expected typed Unavailable, got {other:?}"),
        }
        assert_eq!(
            coord.metrics().densified_jobs.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "streamed jobs are never densified"
        );
        coord.shutdown();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn streamed_multi_batch_all_answered_in_one_walk() {
        let (x, _, _) = planted_streamed(422, 200, 12, 5, "svc_multi");
        let path = x.path().to_path_buf();
        let mut rng = Rng::seed(423);
        let members: Vec<(u64, Vec<f32>)> = (0..4u64)
            .map(|i| {
                let a: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
                let y = x.to_mat().unwrap().matvec(&a);
                (i, y)
            })
            .collect();
        let job = super::super::request::SolveJob {
            x: super::super::request::SharedMatrix::Streamed(x),
            members,
            opts: solver::SolveOptions::accurate(),
            backend: SolverKind::BakMulti,
            trace: None,
            degraded: false,
            job_id: None,
            escalate: false,
        };
        let metrics = Metrics::new();
        let outcomes = execute_job(&job, SolverKind::BakMulti, None, &metrics, None);
        assert_eq!(outcomes.len(), 4);
        assert!(outcomes.iter().all(|o| o.report.is_ok()));
        assert!(
            metrics.stream_chunks_read.load(std::sync::atomic::Ordering::Relaxed) > 0
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn traced_request_returns_telemetry_and_fills_ring() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(430, 300, 20);
        let mut req = SolveRequest::builder(11, x, y).trace(true).build();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::builder().max_sweeps(20).tol(0.0).build();
        let out = coord.solve_blocking(req);
        let rep = out.report.expect("traced solve ok");
        let tel = out.telemetry.expect("telemetry present on traced outcome");
        assert!(tel.trace_id > 0);
        // The trajectory mirrors the solver's residual history.
        assert!(!tel.trajectory.is_empty());
        assert_eq!(tel.trajectory.len(), rep.history.len().min(256));
        for w in tel.trajectory.windows(2) {
            assert!(w[0].sweep < w[1].sweep, "sweeps strictly increase");
        }
        // Spans: queue_wait + route + solve + merge at minimum, all closed.
        let names: Vec<&str> = tel.spans.iter().map(|s| s.name).collect();
        for stage in ["queue_wait", "route", "solve", "merge"] {
            assert!(names.contains(&stage), "{stage} span missing: {names:?}");
        }
        for s in &tel.spans {
            assert!(s.end_ns >= s.start_ns, "span {} never closed", s.name);
        }
        // The completed trace is retained in the service ring.
        let recent = coord.traces().recent(8);
        assert!(recent.iter().any(|t| t.trace_id == tel.trace_id));
        coord.shutdown();
    }

    #[test]
    fn untraced_request_has_no_telemetry() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(431, 60, 8);
        let out = coord.solve_blocking(SolveRequest::new(12, x, y));
        assert!(out.report.is_ok());
        assert!(out.telemetry.is_none());
        assert!(coord.traces().is_empty());
        coord.shutdown();
    }

    #[test]
    fn pool_gauges_flow_through_service_metrics() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 3,
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(416, 100, 10);
        for i in 0..4u64 {
            let _ = coord.solve_blocking(SolveRequest::new(i, x.clone(), y.clone()));
        }
        let j = coord.metrics().to_json();
        assert_eq!(j.get("workers").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("jobs_inflight").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("worker_panics").unwrap().as_f64(), Some(0.0));
        let per_worker = j.get("worker_jobs").unwrap().items();
        assert_eq!(per_worker.len(), 3);
        let total: f64 = per_worker.iter().filter_map(|v| v.as_f64()).sum();
        assert!(total >= 4.0, "every job counted against a worker");
        coord.shutdown();
    }

    #[test]
    fn expired_deadline_returns_typed_error_without_solving() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(440, 200, 16);
        let req = SolveRequest::builder(1, x, y).deadline_ms(0).build();
        let rx = coord.submit_robust(req).expect("deadline requests are admitted");
        let out = rx.recv().unwrap();
        match out.report {
            Err(SolverError::DeadlineExceeded { best, rel_residual, sweeps }) => {
                assert_eq!(best.len(), 16);
                assert_eq!(sweeps, 0);
                assert!(rel_residual >= 1.0 - 1e-12);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().jobs_deadline_exceeded.load(Relaxed), 1);
        assert_eq!(coord.metrics().requests_failed.load(Relaxed), 1);
        coord.shutdown();
    }

    /// Cancels the token from inside the solver's first residual check, so
    /// the mid-solve cancellation path is exercised deterministically.
    struct CancelOnFirstSweep(crate::robust::CancelToken);

    impl crate::obs::SolveProbe for CancelOnFirstSweep {
        fn on_sweep(&self, _sweep: usize, _residual_norm: f64, _elapsed_ns: u64) {
            self.0.cancel();
        }
    }

    #[test]
    fn mid_solve_cancellation_surfaces_best_so_far() {
        let coord = Coordinator::start(CoordinatorConfig::default());
        let (x, y, _) = planted(441, 300, 24);
        let token = crate::robust::CancelToken::manual();
        let mut req = SolveRequest::builder(2, x, y)
            .backend(SolverKind::Bak)
            .opts(
                solver::SolveOptions::builder()
                    .max_sweeps(500)
                    .tol(1e-8)
                    .check_every(1)
                    .cancel(token.clone())
                    .probe(ProbeHandle::new(Arc::new(CancelOnFirstSweep(token))))
                    .build(),
            )
            .build();
        req.opts.thr = 1;
        let out = coord.solve_blocking(req);
        match out.report {
            Err(SolverError::DeadlineExceeded { best, rel_residual, sweeps }) => {
                assert_eq!(sweeps, 1, "cancelled at the first residual check");
                assert_eq!(best.len(), 24);
                assert!(
                    rel_residual < 1.0,
                    "one sweep already improved on the zero solution: {rel_residual}"
                );
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        coord.shutdown();
    }

    #[test]
    fn saturated_gate_sheds_with_retry_hint() {
        let _guard = crate::robust::faults::test_guard();
        crate::robust::faults::install(&crate::robust::FaultPlan {
            queue_stall_ms: 60,
            ..Default::default()
        });
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            max_inflight: 1,
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(442, 100, 10);
        // First robust submission takes the only permit; the scheduler is
        // stalled by the injected fault, so the permit cannot be released
        // before the second submission arrives.
        let rx = coord
            .submit_robust(SolveRequest::builder(1, x.clone(), y.clone()).build())
            .expect("first request admitted");
        let shed = coord.submit_robust(SolveRequest::builder(2, x, y).build());
        match shed {
            Err(SolverError::Overloaded { retry_after_ms }) => {
                assert!((25..=5000).contains(&retry_after_ms), "hint {retry_after_ms}ms");
            }
            Err(other) => panic!("expected Overloaded, got {other:?}"),
            Ok(_) => panic!("expected Overloaded, got admission"),
        }
        crate::robust::faults::clear();
        assert!(rx.recv().unwrap().report.is_ok());
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().jobs_shed.load(Relaxed), 1);
        coord.shutdown();
    }

    #[test]
    fn saturated_gate_degrades_when_configured() {
        let _guard = crate::robust::faults::test_guard();
        crate::robust::faults::install(&crate::robust::FaultPlan {
            queue_stall_ms: 60,
            ..Default::default()
        });
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            max_inflight: 1,
            degraded_sweeps: Some(2),
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(443, 120, 12);
        let rx1 = coord
            .submit_robust(SolveRequest::builder(1, x.clone(), y.clone()).build())
            .expect("first request admitted");
        let rx2 = coord
            .submit_robust(SolveRequest::builder(2, x, y).build())
            .expect("degraded mode admits past the gate");
        crate::robust::faults::clear();
        let out1 = rx1.recv().unwrap();
        let out2 = rx2.recv().unwrap();
        assert!(!out1.degraded);
        assert!(out2.degraded, "second request answered in degraded mode");
        assert_eq!(out2.backend, SolverKind::Bak);
        let rep = out2.report.expect("degraded solve still answers");
        assert!(rep.sweeps <= 2, "sweep budget clamped: {}", rep.sweeps);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().degraded_solves.load(Relaxed), 1);
        assert_eq!(coord.metrics().jobs_shed.load(Relaxed), 0);
        coord.shutdown();
    }

    #[test]
    fn escalation_ladder_orders_bak_cgls_qr() {
        assert_eq!(escalation_ladder(SolverKind::Bak), &[SolverKind::Cgls, SolverKind::Qr]);
        assert_eq!(escalation_ladder(SolverKind::Cgls), &[SolverKind::Qr]);
        assert!(escalation_ladder(SolverKind::Qr).is_empty());
        // Off-ladder kinds start above BAK: retrying the same iteration
        // family against the same conditioning fails the same way.
        assert_eq!(escalation_ladder(SolverKind::Bakp), &[SolverKind::Cgls, SolverKind::Qr]);
        assert_eq!(
            escalation_ladder(SolverKind::Kaczmarz),
            &[SolverKind::Cgls, SolverKind::Qr]
        );
    }

    #[test]
    fn journal_file_names_are_sanitised_and_collision_free() {
        let traversal = journal_file_name("../../etc/passwd");
        assert!(!traversal.contains('/'), "{traversal}");
        assert!(traversal.ends_with(".ckpt"));
        // Distinct ids that sanitise to the same stem still get distinct
        // files (the CRC of the full id disambiguates).
        assert_ne!(journal_file_name("job:1"), journal_file_name("job?1"));
        // Deterministic: resubmission finds the same file.
        assert_eq!(journal_file_name("job-1"), journal_file_name("job-1"));
    }

    fn temp_journal(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("pallas_journal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn durable_job_checkpoints_and_clears_journal_on_success() {
        let dir = temp_journal("success");
        let coord = Coordinator::start(CoordinatorConfig {
            journal_dir: Some(dir.clone()),
            checkpoint_every: 1,
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(450, 200, 16);
        let mut req = SolveRequest::builder(1, x, y).job_id("job-ok").build();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::builder()
            .max_sweeps(30)
            .tol(0.0)
            .check_every(1)
            .build();
        let out = coord.solve_blocking(req);
        assert!(out.report.is_ok());
        assert!(!out.resumed, "no prior checkpoint to resume from");
        use std::sync::atomic::Ordering::Relaxed;
        assert!(coord.metrics().checkpoints_written.load(Relaxed) > 0);
        assert_eq!(coord.metrics().resumes.load(Relaxed), 0);
        // The journal entry is spent once the solve finishes.
        let left: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(left.is_empty(), "journal not cleared: {left:?}");
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resubmitted_job_id_resumes_bit_identically() {
        let dir = temp_journal("resume");
        std::fs::create_dir_all(&dir).unwrap();
        let (x, y, _) = planted(451, 240, 18);
        let mk_opts = |sweeps| {
            solver::SolveOptions::builder()
                .max_sweeps(sweeps)
                .tol(0.0)
                .check_every(1)
                .build()
        };
        // Reference: six uninterrupted sweeps through the same registry
        // adapter the guarded path dispatches to.
        let bak = solver_for(SolverKind::Bak).unwrap();
        let p = Problem::new(&x, &y).unwrap();
        let full = bak.solve(&p, &mk_opts(6)).unwrap();
        // "Crash" after three sweeps: the journal holds what the
        // checkpoint probe would have written at sweep 3.
        let part = bak.solve(&p, &mk_opts(3)).unwrap();
        let opts = mk_opts(3);
        Checkpoint {
            job_id: "resume-key".into(),
            solver: "bak".into(),
            sweeps: part.sweeps as u64,
            seed: opts.seed,
            a: part.a.clone(),
            e: part.e.clone(),
        }
        .save_atomic(&dir.join(journal_file_name("resume-key")))
        .unwrap();

        // Re-submission under the same job id picks the checkpoint up and
        // runs the remaining three sweeps.
        let coord = Coordinator::start(CoordinatorConfig {
            journal_dir: Some(dir.clone()),
            ..CoordinatorConfig::default()
        });
        let mut req = SolveRequest::builder(2, x, y).job_id("resume-key").build();
        req.backend = SolverKind::Bak;
        req.opts = mk_opts(3);
        let out = coord.solve_blocking(req);
        assert!(out.resumed, "checkpoint not picked up");
        let rep = out.report.expect("resumed solve ok");
        assert_eq!(rep.a, full.a, "resume is not bit-identical");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().resumes.load(Relaxed), 1);
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incompatible_checkpoint_is_ignored_and_solve_starts_cold() {
        let dir = temp_journal("mismatch");
        std::fs::create_dir_all(&dir).unwrap();
        let (x, y, _) = planted(453, 100, 10);
        // A checkpoint from a *different* solver under the same id: the
        // guarded path must refuse to splice it in.
        Checkpoint {
            job_id: "cold-key".into(),
            solver: "cgls".into(),
            sweeps: 5,
            seed: solver::SolveOptions::default().seed,
            a: vec![0.5; 10],
            e: y.clone(),
        }
        .save_atomic(&dir.join(journal_file_name("cold-key")))
        .unwrap();
        let coord = Coordinator::start(CoordinatorConfig {
            journal_dir: Some(dir.clone()),
            ..CoordinatorConfig::default()
        });
        let mut req = SolveRequest::builder(3, x, y).job_id("cold-key").build();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        assert!(!out.resumed, "incompatible checkpoint must not resume");
        assert!(out.report.is_ok());
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().resumes.load(Relaxed), 0);
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn breakdown_escalates_up_the_ladder_when_asked() {
        // An intentionally hair-trigger watchdog (any check that fails to
        // improve on the best residual trips it) stands in for a genuine
        // numerical breakdown: deterministic, and exercises the same
        // abort-and-climb machinery.
        let coord = Coordinator::start(CoordinatorConfig {
            watchdog: crate::robust::WatchdogConfig {
                stagnation_patience: 1,
                stagnation_epsilon: 1.0,
                ..crate::robust::WatchdogConfig::default()
            },
            ..CoordinatorConfig::default()
        });
        let (x, y, a_true) = planted(452, 120, 12);
        let mut req = SolveRequest::builder(4, x, y).escalate(true).build();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::builder()
            .max_sweeps(50)
            .tol(0.0)
            .check_every(1)
            .build();
        let out = coord.solve_blocking(req);
        // BAK trips the watchdog; so does CGLS (it reports residuals
        // through the same probe). QR is direct — it never touches the
        // probe and cannot trip — so it answers.
        assert_eq!(out.escalated_to, Some(SolverKind::Qr));
        assert_eq!(out.backend, SolverKind::Qr);
        let rep = out.report.expect("escalated solve answers");
        assert!(rep.a.iter().all(|v| v.is_finite()));
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().escalations.load(Relaxed), 2);
        coord.shutdown();
    }

    #[test]
    fn clustered_kaczmarz_par_is_bit_identical_to_in_process() {
        // Two real TCP workers behind a clustered coordinator: the
        // sharded result must equal solve_kaczmarz_par at the same
        // (seed, shards = threads), bit for bit.
        use crate::cluster::{WorkerCore, WorkerServer};
        let w1 = WorkerServer::bind(Arc::new(WorkerCore::new("svc-w1")), 0).unwrap();
        let w2 = WorkerServer::bind(Arc::new(WorkerCore::new("svc-w2")), 0).unwrap();
        let coord = Coordinator::start(CoordinatorConfig {
            cluster: Some(crate::cluster::ClusterConfig {
                workers: vec![w1.addr().to_string(), w2.addr().to_string()],
                shards: None,
                heartbeat_ms: 0,
            }),
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(460, 48, 6);
        let opts = solver::SolveOptions::builder()
            .max_sweeps(12)
            .tol(1e-10)
            .threads(3)
            .build();
        let reference = crate::parallel::solve_kaczmarz_par(&x, &y, &opts);
        let mut req = SolveRequest::new(1, x.clone(), y.clone());
        req.backend = SolverKind::KaczmarzPar;
        req.opts = opts;
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::KaczmarzPar);
        assert!(!out.resharded, "no worker died");
        let rep = out.report.expect("clustered solve ok");
        assert_eq!(rep.a, reference.a, "iterate differs from in-process");
        assert_eq!(rep.e, reference.e, "residual differs from in-process");
        assert_eq!(rep.history, reference.history);
        assert_eq!(rep.sweeps, reference.sweeps);
        assert_eq!(rep.stop, reference.stop);
        use std::sync::atomic::Ordering::Relaxed;
        let m = coord.metrics();
        assert!(m.shards_dispatched.load(Relaxed) >= 3, "3 shards per round");
        assert_eq!(m.sync_rounds.load(Relaxed), rep.sweeps as u64);
        assert_eq!(m.reshards.load(Relaxed), 0);
        assert_eq!(m.cluster_workers.load(Relaxed), 2);
        coord.shutdown();
        w1.stop();
        w2.stop();
    }

    #[test]
    fn clustered_coordinator_keeps_non_sharding_backends_in_process() {
        // A dead roster must not affect kinds without supports_sharding:
        // they never touch the cluster path.
        let coord = Coordinator::start(CoordinatorConfig {
            cluster: Some(crate::cluster::ClusterConfig {
                workers: vec!["127.0.0.1:9".into()], // unreachable
                shards: None,
                heartbeat_ms: 0,
            }),
            ..CoordinatorConfig::default()
        });
        let (x, y, a_true) = planted(461, 200, 16);
        let mut req = SolveRequest::new(2, x, y);
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::accurate();
        let out = coord.solve_blocking(req);
        assert_eq!(out.backend, SolverKind::Bak);
        let rep = out.report.expect("in-process solve unaffected by dead cluster");
        assert!(crate::util::stats::rel_l2(&rep.a, &a_true) < 1e-3);
        // But a sharding kind against the dead roster fails typed.
        let (x, y, _) = planted(462, 40, 4);
        let mut req = SolveRequest::new(3, x, y);
        req.backend = SolverKind::KaczmarzPar;
        req.opts.threads = 2;
        let out = coord.solve_blocking(req);
        assert!(
            matches!(out.report, Err(SolverError::Service(_))),
            "sharded solve against an all-dead roster is a typed Service error"
        );
        coord.shutdown();
    }

    #[test]
    fn breakdown_without_escalation_is_a_typed_error() {
        let dir = temp_journal("breakdown");
        let coord = Coordinator::start(CoordinatorConfig {
            watchdog: crate::robust::WatchdogConfig {
                stagnation_patience: 1,
                stagnation_epsilon: 1.0,
                ..crate::robust::WatchdogConfig::default()
            },
            // A journal dir so the job takes the guarded path via job_id.
            journal_dir: Some(dir.clone()),
            ..CoordinatorConfig::default()
        });
        let (x, y, _) = planted(454, 120, 12);
        let mut req = SolveRequest::builder(5, x, y).job_id("doomed").build();
        req.backend = SolverKind::Bak;
        req.opts = solver::SolveOptions::builder()
            .max_sweeps(50)
            .tol(0.0)
            .check_every(1)
            .build();
        let out = coord.solve_blocking(req);
        match out.report {
            Err(SolverError::NumericalBreakdown { detail, sweeps }) => {
                assert!(detail.contains("stagnating"), "{detail}");
                assert!(sweeps >= 1);
            }
            other => panic!("expected NumericalBreakdown, got {other:?}"),
        }
        assert!(out.escalated_to.is_none());
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics().escalations.load(Relaxed), 0);
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
