//! Shard planning: which contiguous slice of the system each worker
//! gets, derived with the *same* partition the in-process solvers use so
//! the cluster's block structure — and therefore its RNG streams and
//! merge order — matches `solve_kaczmarz_par` / `solve_bak_par` exactly.

use std::ops::Range;

use crate::api::SolverKind;
use crate::linalg::Mat;
use crate::parallel::partition_ranges;

/// Which dimension a kind shards over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardAxis {
    /// `kaczmarz_par`: contiguous row blocks (the paper's tall systems;
    /// a wide system is solved row-sharded after transposition upstream).
    Rows,
    /// `bak_par`: contiguous column blocks — the transposed view of the
    /// same idea, and column-major storage makes extraction a memcpy.
    Cols,
}

/// The shard plan for one solve: axis plus the contiguous ranges, in
/// block order (which is also merge order and RNG-stream order).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub axis: ShardAxis,
    pub ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Plan `shards` blocks for `kind` over an `obs x vars` system.
    /// `shards` plays the role of `SolveOptions::threads` in-process:
    /// [`partition_ranges`] clamps it to the sharded dimension, exactly
    /// as the solvers do. `None` for kinds without `supports_sharding`.
    pub fn plan(kind: SolverKind, obs: usize, vars: usize, shards: usize) -> Option<ShardPlan> {
        let axis = match kind {
            SolverKind::KaczmarzPar => ShardAxis::Rows,
            SolverKind::BakPar => ShardAxis::Cols,
            _ => return None,
        };
        let n = match axis {
            ShardAxis::Rows => obs,
            ShardAxis::Cols => vars,
        };
        Some(ShardPlan { axis, ranges: partition_ranges(n, shards.max(1)) })
    }

    /// Block count (the in-process `nb`).
    pub fn nb(&self) -> usize {
        self.ranges.len()
    }

    /// Extract shard `b`'s column-major submatrix.
    pub fn extract(&self, x: &Mat, b: usize) -> Mat {
        match self.axis {
            ShardAxis::Rows => extract_rows(x, &self.ranges[b]),
            ShardAxis::Cols => extract_cols(x, &self.ranges[b]),
        }
    }
}

/// Full-matrix squared row norms via the same single column-major
/// `mul_add` pass the in-process kaczmarz solver uses — the driver needs
/// the global vector for block masses and the trivial all-zero path, and
/// the accumulation order must match bit-for-bit.
pub fn row_norms_sq(x: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; x.rows()];
    for j in 0..x.cols() {
        for (rn, &v) in out.iter_mut().zip(x.col(j)) {
            *rn = v.mul_add(v, *rn);
        }
    }
    out
}

/// Rows `range` of `x` as a fresh column-major `range.len() x vars`
/// matrix. The strided gather preserves per-column contiguity, which is
/// what keeps the worker's row norms and strided row ops bit-identical
/// to the full matrix restricted to those rows.
pub fn extract_rows(x: &Mat, range: &Range<usize>) -> Mat {
    let rows = range.len();
    let mut data = Vec::with_capacity(rows * x.cols());
    for j in 0..x.cols() {
        data.extend_from_slice(&x.col(j)[range.clone()]);
    }
    Mat::from_col_major(rows, x.cols(), data)
}

/// Columns `range` of `x` as a fresh column-major `obs x range.len()`
/// matrix — one contiguous copy in column-major storage.
pub fn extract_cols(x: &Mat, range: &Range<usize>) -> Mat {
    let rows = x.rows();
    let data = x.as_slice()[range.start * rows..range.end * rows].to_vec();
    Mat::from_col_major(rows, range.len(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn plan_matches_in_process_partition() {
        let p = ShardPlan::plan(SolverKind::KaczmarzPar, 10, 4, 3).unwrap();
        assert_eq!(p.axis, ShardAxis::Rows);
        assert_eq!(p.ranges, partition_ranges(10, 3));
        let p = ShardPlan::plan(SolverKind::BakPar, 10, 4, 3).unwrap();
        assert_eq!(p.axis, ShardAxis::Cols);
        assert_eq!(p.ranges, partition_ranges(4, 3));
        // More shards than the axis has entries clamps, like threads do.
        assert_eq!(ShardPlan::plan(SolverKind::BakPar, 10, 2, 8).unwrap().nb(), 2);
        // Non-shardable kinds have no plan.
        assert!(ShardPlan::plan(SolverKind::Bak, 10, 4, 2).is_none());
        assert!(ShardPlan::plan(SolverKind::Qr, 10, 4, 2).is_none());
    }

    #[test]
    fn extraction_matches_source_values() {
        let mut rng = Rng::seed(31);
        let x = Mat::randn(&mut rng, 7, 5);
        let rs = extract_rows(&x, &(2..5));
        assert_eq!((rs.rows(), rs.cols()), (3, 5));
        for j in 0..5 {
            assert_eq!(rs.col(j), &x.col(j)[2..5]);
        }
        let cs = extract_cols(&x, &(1..4));
        assert_eq!((cs.rows(), cs.cols()), (7, 3));
        for (local, global) in (1..4).enumerate() {
            assert_eq!(cs.col(local), x.col(global));
        }
    }
}
