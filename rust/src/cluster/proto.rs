//! The v1.2 message vocabulary: JSON builders and parsers for `join`,
//! `heartbeat`, and `shard_solve`, shared by the coordinator-side driver
//! and the worker-side core so the two ends cannot drift.
//!
//! Float transport is bit-exact: every f32 widens to f64 (exact), the
//! JSON writer prints the shortest decimal that round-trips the f64, and
//! the reader narrows back — so a shard iterate survives any number of
//! wire crossings unchanged, which is a precondition for the driver's
//! bit-identity guarantee.

use crate::api::{SolverError, SolverKind};
use crate::util::json::{Json, ObjBuilder};

/// Build a `join` request.
pub fn join_request() -> Json {
    ObjBuilder::new().num("v", 1.0).str("cmd", "join").build()
}

/// Build a `heartbeat` request.
pub fn heartbeat_request() -> Json {
    ObjBuilder::new().num("v", 1.0).str("cmd", "heartbeat").build()
}

/// One shard's worth of matrix data, shipped on the first dispatch of a
/// `(job, shard)` pair to a worker (and again after a re-dispatch).
pub struct ShardData<'a> {
    /// Global index of the shard's first row (kaczmarz) / column (bak).
    pub start: usize,
    /// Submatrix shape (`rows x cols`, column-major payload).
    pub rows: usize,
    pub cols: usize,
    /// Column-major submatrix values.
    pub x: &'a [f32],
    /// The shard's slice of the right-hand side (kaczmarz only; empty
    /// for bak, whose shards own columns and read the shared residual).
    pub y: &'a [f32],
}

/// Per-round parameters of a `shard_solve` request.
pub struct ShardRound<'a> {
    /// Cluster job key (scopes the worker's shard cache).
    pub job: &'a str,
    /// Which backend's inner sweep to run.
    pub kind: SolverKind,
    /// Shard ordinal and total shard count — together with `seed` and
    /// `sweep` they key the worker's RNG stream
    /// (`stream_seed(seed, sweep * nb + shard)`), so a re-dispatched
    /// shard draws the identical sample sequence on its new worker.
    pub shard: usize,
    pub nb: usize,
    pub sweep: usize,
    pub seed: u64,
    /// `true` = SolveBak's Shuffled column order for this solve.
    pub shuffled: bool,
    /// Sync vector for this round: the merged iterate `a` (kaczmarz) or
    /// the shared residual `e` (bak).
    pub sync: &'a [f32],
    /// Remaining wall-clock budget for this round, from the job's
    /// cancellation token (None = no deadline armed).
    pub deadline_ms: Option<u64>,
}

/// Build a `shard_solve` request; `data` rides along on first contact.
pub fn shard_solve_request(round: &ShardRound<'_>, data: Option<&ShardData<'_>>) -> Json {
    let mut b = ObjBuilder::new()
        .num("v", 1.0)
        .str("cmd", "shard_solve")
        .str("job", round.job)
        .str("kind", round.kind.as_str())
        .num("shard", round.shard as f64)
        .num("nb", round.nb as f64)
        .num("sweep", round.sweep as f64)
        // u64 seeds exceed f64's exact-integer range; a decimal string
        // crosses the wire losslessly.
        .str("seed", round.seed.to_string())
        .str("order", if round.shuffled { "shuffled" } else { "cyclic" })
        .val("sync", f32s_to_json(round.sync));
    if let Some(ms) = round.deadline_ms {
        b = b.num("deadline_ms", ms as f64);
    }
    if let Some(d) = data {
        b = b.val(
            "data",
            ObjBuilder::new()
                .num("start", d.start as f64)
                .num("rows", d.rows as f64)
                .num("cols", d.cols as f64)
                .val("x", f32s_to_json(d.x))
                .val("y", f32s_to_json(d.y))
                .build(),
        );
    }
    b.build()
}

/// Build the end-of-job `shard_solve` that releases a worker's cached
/// shard data for `job`.
pub fn release_request(job: &str) -> Json {
    ObjBuilder::new()
        .num("v", 1.0)
        .str("cmd", "shard_solve")
        .str("job", job)
        .bool("release", true)
        .build()
}

/// Lossless f32 slice → JSON array (see the module docs).
pub fn f32s_to_json(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// JSON array → f32 vector; `None` when any element is not a number.
pub fn json_to_f32s(j: &Json) -> Option<Vec<f32>> {
    match j {
        Json::Arr(items) => {
            let mut out = Vec::with_capacity(items.len());
            for it in items {
                out.push(it.as_f64()? as f32);
            }
            Some(out)
        }
        _ => None,
    }
}

/// Map a structured `ok: false` reply (or pass an `ok: true` one
/// through) to the coordinator-side error vocabulary, so worker
/// overloads feed the existing retry path and everything else surfaces
/// as a typed failure.
pub fn check_reply(reply: Json) -> Result<Json, SolverError> {
    if reply.get("ok").and_then(Json::as_bool) == Some(true) {
        return Ok(reply);
    }
    let kind = reply.get("error_kind").and_then(Json::as_str).unwrap_or("service");
    let msg = reply
        .get("error")
        .and_then(Json::as_str)
        .unwrap_or("worker replied ok: false")
        .to_string();
    Err(match kind {
        "overloaded" => SolverError::Overloaded {
            retry_after_ms: reply
                .get("retry_after_ms")
                .and_then(Json::as_f64)
                .unwrap_or(25.0) as u64,
        },
        "unsupported" => SolverError::Unsupported(msg),
        "invalid_input" => SolverError::InvalidInput(msg),
        _ => SolverError::Backend { backend: "cluster-worker".into(), reason: msg },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_arrays_roundtrip_bit_exactly() {
        // Awkward values: subnormal, near-max, fractions with no finite
        // decimal expansion.
        let vals: Vec<f32> = vec![
            0.1, 1.0e-40, 3.4e38, 1.0 / 3.0, -7.25, f32::MIN_POSITIVE, -0.0,
        ];
        let wire = f32s_to_json(&vals).to_string();
        let back = json_to_f32s(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            // One benign exception to to_bits equality: the integer fast
            // path of the JSON writer collapses -0.0 to 0 — numerically
            // indistinguishable in every operation the solvers perform.
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        }
    }

    #[test]
    fn shard_solve_request_carries_round_and_data() {
        let sync = vec![1.5f32, -2.0];
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = vec![0.5f32, 0.25];
        let round = ShardRound {
            job: "j1",
            kind: SolverKind::KaczmarzPar,
            shard: 1,
            nb: 4,
            sweep: 7,
            seed: u64::MAX, // would not survive as a JSON number
            shuffled: false,
            sync: &sync,
            deadline_ms: Some(250),
        };
        let data = ShardData { start: 2, rows: 2, cols: 2, x: &x, y: &y };
        let req = shard_solve_request(&round, Some(&data));
        assert_eq!(req.get("cmd").unwrap().as_str(), Some("shard_solve"));
        assert_eq!(req.get("seed").unwrap().as_str(), Some("18446744073709551615"));
        assert_eq!(req.get("deadline_ms").unwrap().as_f64(), Some(250.0));
        let d = req.get("data").unwrap();
        assert_eq!(d.get("start").unwrap().as_usize(), Some(2));
        assert_eq!(json_to_f32s(d.get("x").unwrap()).unwrap(), x);
        // Round-only requests omit the payload.
        let lean = shard_solve_request(&round, None);
        assert!(lean.get("data").is_none());
    }

    #[test]
    fn check_reply_maps_error_kinds() {
        let ok = Json::parse(r#"{"ok": true, "ab": []}"#).unwrap();
        assert!(check_reply(ok).is_ok());
        let over =
            Json::parse(r#"{"ok": false, "error_kind": "overloaded", "retry_after_ms": 40}"#)
                .unwrap();
        assert_eq!(
            check_reply(over).unwrap_err(),
            SolverError::Overloaded { retry_after_ms: 40 }
        );
        let bad = Json::parse(r#"{"ok": false, "error_kind": "invalid_input", "error": "x"}"#)
            .unwrap();
        assert!(matches!(check_reply(bad).unwrap_err(), SolverError::InvalidInput(_)));
        let vague = Json::parse(r#"{"ok": false}"#).unwrap();
        assert!(matches!(check_reply(vague).unwrap_err(), SolverError::Backend { .. }));
    }
}
