//! The worker side of the cluster: [`WorkerCore`] answers the v1.2
//! commands against a per-`(job, shard)` data cache, and
//! [`WorkerServer`] serves it over TCP for `solvebak serve-worker`.
//!
//! A worker is deliberately stateless about the *solve*: all global
//! state (iterate, residual, history, stop decisions) lives on the
//! coordinator. The worker holds only its shard's immutable data —
//! submatrix, per-row norms + sampling CDF (kaczmarz) or per-column
//! inverse norms (bak) — and runs one block inner sweep per
//! `shard_solve` request. Every derived quantity is computed with the
//! same operation sequence the in-process solvers use on the full
//! matrix, which is what makes the round's output bit-identical to the
//! corresponding in-process block (see `solvers.rs`).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::SolverKind;
use crate::linalg::{blas1, Mat};
use crate::parallel::stream_seed;
use crate::util::json::{Json, ObjBuilder};
use crate::util::rng::Rng;

use super::proto;

/// The commands a v1.2 worker speaks (advertised by `join` and by the
/// coordinator's `hello`).
pub const WORKER_COMMANDS: [&str; 4] = ["join", "heartbeat", "shard_solve", "ping"];

/// Immutable per-shard state, cached after the first `shard_solve` that
/// carries `data`.
enum Shard {
    /// A contiguous row block: local submatrix, its slice of y, and the
    /// Strohmer-Vershynin sampling state restricted to the block.
    Kaczmarz {
        x: Mat,
        y: Vec<f32>,
        row_norms_sq: Vec<f32>,
        cdf: Vec<f64>,
        mass: f64,
    },
    /// A contiguous column block: local submatrix and inverse column
    /// norms (zero columns mapped to 0, as in the serial solver).
    Bak { x: Mat, cninv: Vec<f32> },
}

/// Shard-solve request handler: the embeddable heart of a worker node.
/// The coordinator's TCP server embeds one too, so a `serve-tcp`
/// process can also serve shards for *another* coordinator.
pub struct WorkerCore {
    worker_id: String,
    /// Concurrent `shard_solve` cap; 0 = unlimited. A saturated worker
    /// answers `overloaded` + `retry_after_ms`, feeding the
    /// coordinator's existing backoff path.
    max_inflight: usize,
    inflight: AtomicUsize,
    shards: Mutex<HashMap<(String, usize), Arc<Shard>>>,
}

impl WorkerCore {
    pub fn new(worker_id: impl Into<String>) -> Self {
        WorkerCore {
            worker_id: worker_id.into(),
            max_inflight: 0,
            inflight: AtomicUsize::new(0),
            shards: Mutex::new(HashMap::new()),
        }
    }

    /// Cap concurrent `shard_solve`s (0 = unlimited).
    pub fn with_max_inflight(mut self, n: usize) -> Self {
        self.max_inflight = n;
        self
    }

    /// Shards currently cached (reported by `heartbeat`).
    pub fn shards_cached(&self) -> usize {
        self.shards.lock().unwrap().len()
    }

    /// Answer one v1.2 request; always returns a reply object (errors
    /// are structured lines, never dropped connections — same contract
    /// as the coordinator server).
    pub fn handle_request(&self, req: &Json) -> Json {
        if let Some(v) = req.get("v").and_then(Json::as_f64) {
            if v != 1.0 {
                return error_json("unsupported", format!("protocol version {v} not supported"));
            }
        }
        match req.get("cmd").and_then(Json::as_str) {
            Some("ping") => ObjBuilder::new().bool("ok", true).str("pong", "pong").build(),
            Some("join") => {
                let cmds =
                    Json::Arr(WORKER_COMMANDS.iter().map(|c| Json::Str(c.to_string())).collect());
                ObjBuilder::new()
                    .bool("ok", true)
                    .num("proto_version", 1.0)
                    .str("worker_id", self.worker_id.clone())
                    .val("commands", cmds)
                    .build()
            }
            Some("heartbeat") => ObjBuilder::new()
                .bool("ok", true)
                .str("pong", "pong")
                .num("shards_cached", self.shards_cached() as f64)
                .build(),
            Some("shard_solve") => self.shard_solve(req),
            Some(other) => error_json("unsupported", format!("unknown command '{other}'")),
            None => error_json("invalid_input", "worker requests need a \"cmd\"".to_string()),
        }
    }

    fn shard_solve(&self, req: &Json) -> Json {
        let Some(job) = req.get("job").and_then(Json::as_str) else {
            return error_json("invalid_input", "shard_solve needs a \"job\" key".to_string());
        };
        // End-of-job cache release.
        if req.get("release").and_then(Json::as_bool) == Some(true) {
            let mut shards = self.shards.lock().unwrap();
            let before = shards.len();
            shards.retain(|(j, _), _| j != job);
            let released = before - shards.len();
            return ObjBuilder::new().bool("ok", true).num("released", released as f64).build();
        }

        // Admission gate, mirroring the coordinator's load shedding.
        let _guard = match InflightGuard::enter(self) {
            Some(g) => g,
            None => {
                return ObjBuilder::new()
                    .bool("ok", false)
                    .str("error_kind", "overloaded")
                    .str("error", "worker inflight cap reached")
                    .num("retry_after_ms", 25.0)
                    .build()
            }
        };

        let kind = match req.get("kind").and_then(Json::as_str).map(str::parse::<SolverKind>) {
            Some(Ok(k @ (SolverKind::KaczmarzPar | SolverKind::BakPar))) => k,
            _ => {
                return error_json(
                    "invalid_input",
                    "shard_solve kind must be kaczmarz_par or bak_par".to_string(),
                )
            }
        };
        let (Some(shard), Some(nb), Some(sweep)) = (
            req.get("shard").and_then(Json::as_usize),
            req.get("nb").and_then(Json::as_usize),
            req.get("sweep").and_then(Json::as_usize),
        ) else {
            return error_json("invalid_input", "shard_solve needs shard/nb/sweep".to_string());
        };
        // Seeds cross the wire as decimal strings (u64 > 2^53 would not
        // survive a JSON number).
        let Some(seed) = req.get("seed").and_then(Json::as_str).and_then(|s| s.parse().ok())
        else {
            return error_json("invalid_input", "shard_solve needs a string \"seed\"".to_string());
        };
        let shuffled = req.get("order").and_then(Json::as_str) == Some("shuffled");
        let Some(sync) = req.get("sync").and_then(|j| proto::json_to_f32s(j)) else {
            return error_json("invalid_input", "shard_solve needs a \"sync\" array".to_string());
        };

        let key = (job.to_string(), shard);
        if let Some(data) = req.get("data") {
            match build_shard(kind, data) {
                Ok(sh) => {
                    self.shards.lock().unwrap().insert(key.clone(), Arc::new(sh));
                }
                Err(msg) => return error_json("invalid_input", msg),
            }
        }
        // Clone the Arc out so a slow round does not serialize the other
        // shards this worker holds.
        let Some(sh) = self.shards.lock().unwrap().get(&key).cloned() else {
            return error_json(
                "invalid_input",
                format!("no cached data for job '{job}' shard {shard}; resend with \"data\""),
            );
        };

        match (kind, sh.as_ref()) {
            (SolverKind::KaczmarzPar, Shard::Kaczmarz { x, y, row_norms_sq, cdf, mass }) => {
                let ab = kaczmarz_round(x, y, row_norms_sq, cdf, *mass, sync, sweep, nb, shard, seed);
                ObjBuilder::new()
                    .bool("ok", true)
                    .num("shard", shard as f64)
                    .val("ab", proto::f32s_to_json(&ab))
                    .build()
            }
            (SolverKind::BakPar, Shard::Bak { x, cninv }) => {
                let (da, e_loc) = bak_round(x, cninv, sync, sweep, nb, shard, seed, shuffled);
                ObjBuilder::new()
                    .bool("ok", true)
                    .num("shard", shard as f64)
                    .val("da", proto::f32s_to_json(&da))
                    .val("e_loc", proto::f32s_to_json(&e_loc))
                    .build()
            }
            _ => error_json(
                "invalid_input",
                format!("shard {shard} of job '{job}' was cached for a different kind"),
            ),
        }
    }
}

/// RAII inflight counter; `None` when the cap is hit.
struct InflightGuard<'a>(&'a WorkerCore);

impl<'a> InflightGuard<'a> {
    fn enter(core: &'a WorkerCore) -> Option<Self> {
        let n = core.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if core.max_inflight != 0 && n > core.max_inflight {
            core.inflight.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        Some(InflightGuard(core))
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

fn error_json(kind: &str, msg: String) -> Json {
    ObjBuilder::new().bool("ok", false).str("error_kind", kind).str("error", msg).build()
}

/// Build the cached shard state from a `data` payload. Every derived
/// quantity replicates the in-process solver's operation sequence on
/// the equivalent slice of the full matrix.
fn build_shard(kind: SolverKind, data: &Json) -> Result<Shard, String> {
    let (Some(rows), Some(cols)) = (
        data.get("rows").and_then(Json::as_usize),
        data.get("cols").and_then(Json::as_usize),
    ) else {
        return Err("shard data needs rows/cols".to_string());
    };
    let Some(x) = data.get("x").and_then(|j| proto::json_to_f32s(j)) else {
        return Err("shard data needs an \"x\" array".to_string());
    };
    if x.len() != rows * cols || rows == 0 || cols == 0 {
        return Err(format!("shard data: x has {} values for a {rows}x{cols} block", x.len()));
    }
    let x = Mat::from_col_major(rows, cols, x);
    match kind {
        SolverKind::KaczmarzPar => {
            let Some(y) = data.get("y").and_then(|j| proto::json_to_f32s(j)) else {
                return Err("kaczmarz shard data needs a \"y\" array".to_string());
            };
            if y.len() != rows {
                return Err(format!("shard data: y has {} values for {rows} rows", y.len()));
            }
            // Same column-major mul_add pass as the full-matrix row
            // norms, restricted to this block's rows — bit-identical.
            let mut row_norms_sq = vec![0.0f32; rows];
            for j in 0..cols {
                for (rn, &v) in row_norms_sq.iter_mut().zip(x.col(j)) {
                    *rn = v.mul_add(v, *rn);
                }
            }
            // Block CDF exactly as the in-process Block construction.
            let mass: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
            let mut cdf = Vec::with_capacity(rows);
            let mut acc = 0.0f64;
            for &v in &row_norms_sq {
                acc += if mass > 0.0 { v as f64 / mass } else { 0.0 };
                cdf.push(acc);
            }
            Ok(Shard::Kaczmarz { x, y, row_norms_sq, cdf, mass })
        }
        SolverKind::BakPar => {
            // Per-column norms only read their own column, so the local
            // values equal the full matrix's over this block.
            let cninv = crate::solver::colnorms_inv(&x);
            Ok(Shard::Bak { x, cninv })
        }
        _ => Err("unsupported shard kind".to_string()),
    }
}

/// One kaczmarz block inner sweep — the body of `kaczmarz_par_generic`'s
/// per-block closure, on local indices. The RNG stream is keyed by
/// `(seed, sweep * nb + shard)`, never by worker identity, so a
/// re-dispatched shard draws the identical sample sequence.
#[allow(clippy::too_many_arguments)]
fn kaczmarz_round(
    x: &Mat,
    y: &[f32],
    row_norms_sq: &[f32],
    cdf: &[f64],
    mass: f64,
    a: Vec<f32>,
    sweep: usize,
    nb: usize,
    shard: usize,
    seed: u64,
) -> Vec<f32> {
    let mut ab = a;
    if mass == 0.0 {
        return ab; // all-zero rows; merge weight 0 on the coordinator
    }
    let rows = x.rows();
    let xs = x.as_slice();
    let mut rng = Rng::seed(stream_seed(seed, (sweep * nb + shard) as u64));
    for _ in 0..rows {
        let u = rng.uniform();
        let k = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(k) => k,
            Err(k) => k.min(rows - 1),
        };
        let nrm = row_norms_sq[k];
        if nrm == 0.0 {
            continue;
        }
        let ri = y[k] - blas1::dot_strided(&xs[k..], rows, &ab);
        blas1::axpy_strided(ri / nrm, &xs[k..], rows, &mut ab);
    }
    ab
}

/// One bak block inner sweep — the body of `bak_par_generic`'s per-block
/// closure, on local column indices (the Fisher-Yates permutation is
/// value-agnostic, so shuffling local indices draws the identical
/// permutation the in-process block draws over global ones).
#[allow(clippy::too_many_arguments)]
fn bak_round(
    x: &Mat,
    cninv: &[f32],
    e: Vec<f32>,
    sweep: usize,
    nb: usize,
    shard: usize,
    seed: u64,
    shuffled: bool,
) -> (Vec<f32>, Vec<f32>) {
    let mut e_loc = e;
    let blk_len = x.cols();
    let mut da = vec![0.0f32; blk_len];
    let mut order: Vec<usize> = (0..blk_len).collect();
    if shuffled {
        let mut rng = Rng::seed(stream_seed(seed, (sweep * nb + shard) as u64));
        rng.shuffle(&mut order);
    }
    for &j in &order {
        let cn = cninv[j];
        if cn == 0.0 {
            continue; // zero column
        }
        da[j] = blas1::cd_step(x.col(j), &mut e_loc, cn);
    }
    (da, e_loc)
}

/// A newline-JSON TCP front-end over a [`WorkerCore`]: one request
/// object per line, one reply per line, connection-per-thread — the
/// same wire discipline as the coordinator server.
pub struct WorkerServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
}

impl WorkerServer {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) and start accepting.
    pub fn bind(core: Arc<WorkerCore>, port: u16) -> std::io::Result<WorkerServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept = std::thread::Builder::new()
            .name("cluster-worker-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let core = core.clone();
                    let stop3 = stop2.clone();
                    let _ = std::thread::Builder::new()
                        .name("cluster-worker-conn".into())
                        .spawn(move || serve_conn(stream, &core, &stop3));
                }
            })?;
        Ok(WorkerServer { addr, stop, accept_thread: Mutex::new(Some(accept)) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread (live connections
    /// drain on their own).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(stream: TcpStream, core: &WorkerCore, stop: &AtomicBool) {
    let Ok(peer) = stream.try_clone() else { return };
    let mut writer = peer;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let reply = match Json::parse(line) {
            Ok(req) => {
                if req.get("cmd").and_then(Json::as_str) == Some("shutdown") {
                    let bye =
                        ObjBuilder::new().bool("ok", true).str("bye", "bye").build().to_string();
                    let _ = writeln!(writer, "{bye}");
                    stop.store(true, Ordering::SeqCst);
                    return;
                }
                core.handle_request(&req)
            }
            Err(e) => ObjBuilder::new()
                .bool("ok", false)
                .str("error_kind", "bad_json")
                .str("error", format!("{e}"))
                .build(),
        };
        let line = reply.to_string();
        if writeln!(writer, "{line}").is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolveOptions;
    use crate::util::rng::Rng as TestRng;

    fn kaczmarz_data_json(x: &Mat, y: &[f32]) -> Json {
        ObjBuilder::new()
            .num("start", 0.0)
            .num("rows", x.rows() as f64)
            .num("cols", x.cols() as f64)
            .val("x", proto::f32s_to_json(x.as_slice()))
            .val("y", proto::f32s_to_json(y))
            .build()
    }

    #[test]
    fn join_and_heartbeat_report_identity_and_cache() {
        let core = WorkerCore::new("w0");
        let j = core.handle_request(&Json::parse(r#"{"cmd": "join"}"#).unwrap());
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("worker_id").unwrap().as_str(), Some("w0"));
        let cmds: Vec<&str> =
            j.get("commands").unwrap().items().iter().filter_map(Json::as_str).collect();
        assert!(cmds.contains(&"shard_solve"));
        let h = core.handle_request(&Json::parse(r#"{"cmd": "heartbeat"}"#).unwrap());
        assert_eq!(h.get("shards_cached").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn unknown_command_and_bad_version_are_unsupported() {
        let core = WorkerCore::new("w0");
        let r = core.handle_request(&Json::parse(r#"{"cmd": "frobnicate"}"#).unwrap());
        assert_eq!(r.get("error_kind").unwrap().as_str(), Some("unsupported"));
        let r = core.handle_request(&Json::parse(r#"{"v": 3, "cmd": "ping"}"#).unwrap());
        assert_eq!(r.get("error_kind").unwrap().as_str(), Some("unsupported"));
    }

    #[test]
    fn single_shard_round_matches_in_process_solver_block() {
        // One shard covering the whole system: a kaczmarz round must
        // reproduce solve_kaczmarz_par's first sweep at threads=1.
        let mut rng = TestRng::seed(77);
        let x = Mat::randn(&mut rng, 30, 6);
        let a_true: Vec<f32> = (0..6).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        let opts = SolveOptions::default();

        let core = WorkerCore::new("w0");
        let round = proto::ShardRound {
            job: "t1",
            kind: SolverKind::KaczmarzPar,
            shard: 0,
            nb: 1,
            sweep: 0,
            seed: opts.seed,
            shuffled: false,
            sync: &vec![0.0f32; 6],
            deadline_ms: None,
        };
        let mut req = proto::shard_solve_request(&round, None);
        if let Json::Obj(m) = &mut req {
            m.insert("data".into(), kaczmarz_data_json(&x, &y));
        }
        let reply = core.handle_request(&req);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(true), "{reply:?}");
        let ab = proto::json_to_f32s(reply.get("ab").unwrap()).unwrap();

        let mut o = opts.clone();
        o.max_sweeps = 1;
        o.tol = 0.0;
        o.threads = 1;
        let rep = crate::parallel::solve_kaczmarz_par(&x, &y, &o);
        // With one block the merge weight is 1, so the merged iterate
        // IS the block iterate.
        assert_eq!(ab, rep.a, "worker round must equal the in-process block sweep");

        // The shard is cached now: a data-free round for sweep 1 works.
        let round2 = proto::ShardRound { sweep: 1, sync: &ab, ..round };
        let reply2 = core.handle_request(&proto::shard_solve_request(&round2, None));
        assert_eq!(reply2.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(core.shards_cached(), 1);

        // Release drops the cache; the next data-free round is rejected.
        let rel = core.handle_request(&proto::release_request("t1"));
        assert_eq!(rel.get("released").unwrap().as_usize(), Some(1));
        let reply3 = core.handle_request(&proto::shard_solve_request(&round2, None));
        assert_eq!(reply3.get("error_kind").unwrap().as_str(), Some("invalid_input"));
    }

    #[test]
    fn inflight_cap_sheds_with_retry_hint() {
        // Cap 0 is unlimited; a saturated gate answers overloaded. The
        // gate counts entry, so driving it via a zero-cap... instead
        // assert the guard arithmetic directly with max_inflight = 1 and
        // a manually held guard.
        let core = WorkerCore::new("w0").with_max_inflight(1);
        let g = InflightGuard::enter(&core).expect("first slot free");
        assert!(InflightGuard::enter(&core).is_none(), "cap of 1 is full");
        drop(g);
        assert!(InflightGuard::enter(&core).is_some(), "slot freed");
    }

    #[test]
    fn tcp_server_roundtrips_and_stops() {
        let core = Arc::new(WorkerCore::new("w-tcp"));
        let srv = WorkerServer::bind(core, 0).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"{\"cmd\": \"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "{line}");
        srv.stop();
    }
}
