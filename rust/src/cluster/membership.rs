//! The coordinator's view of the worker set: one slot per configured
//! worker, per-slot liveness, and an optional background heartbeat that
//! keeps a `cluster_workers` gauge honest between solves.
//!
//! Liveness here is *global* (is the process reachable); the driver
//! additionally keeps a per-job ban list, because a worker that died and
//! came back has lost its shard cache — global revival must not trick an
//! in-flight solve into trusting it again without resending data.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

use super::proto;
use super::transport::{LoopbackTransport, TcpTransport, Transport};
use super::worker::WorkerCore;

struct WorkerSlot {
    addr: String,
    transport: Arc<dyn Transport>,
    alive: AtomicBool,
}

/// The worker roster. Construction never fails — unreachable workers
/// start dead and a later [`Membership::probe`] can revive them.
pub struct Membership {
    slots: Vec<WorkerSlot>,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Mutex<Option<JoinHandle<()>>>,
}

impl Membership {
    /// Roster over explicit transports (tests/benches); every slot
    /// starts alive.
    pub fn from_transports(workers: Vec<(String, Arc<dyn Transport>)>) -> Self {
        let slots = workers
            .into_iter()
            .map(|(addr, transport)| WorkerSlot {
                addr,
                transport,
                alive: AtomicBool::new(true),
            })
            .collect();
        Membership {
            slots,
            hb_stop: Arc::new(AtomicBool::new(false)),
            hb_thread: Mutex::new(None),
        }
    }

    /// Roster over TCP workers. Each address gets a `join` probe up
    /// front: responders start alive (and log their worker id),
    /// non-responders start dead.
    pub fn connect(addrs: &[String]) -> Self {
        let slots: Vec<WorkerSlot> = addrs
            .iter()
            .map(|addr| {
                let transport: Arc<dyn Transport> = Arc::new(TcpTransport::new(addr.clone()));
                let alive = match transport
                    .request(&proto::join_request())
                    .and_then(proto::check_reply)
                {
                    Ok(reply) => {
                        let id = reply
                            .get("worker_id")
                            .and_then(Json::as_str)
                            .unwrap_or("?")
                            .to_string();
                        crate::debug!("cluster", "worker {addr} joined as '{id}'");
                        true
                    }
                    Err(e) => {
                        crate::debug!("cluster", "worker {addr} unreachable at join: {e}");
                        false
                    }
                };
                WorkerSlot { addr: addr.clone(), transport, alive: AtomicBool::new(alive) }
            })
            .collect();
        Membership {
            slots,
            hb_stop: Arc::new(AtomicBool::new(false)),
            hb_thread: Mutex::new(None),
        }
    }

    /// In-process roster of `n` loopback workers (tests/benches). Also
    /// returns the transports so a test can [`LoopbackTransport::fail_after_requests`]
    /// one of them mid-solve.
    pub fn loopback(n: usize, max_inflight: usize) -> (Self, Vec<Arc<LoopbackTransport>>) {
        let mut transports = Vec::with_capacity(n);
        let mut workers: Vec<(String, Arc<dyn Transport>)> = Vec::with_capacity(n);
        for i in 0..n {
            let core =
                Arc::new(WorkerCore::new(format!("loopback-{i}")).with_max_inflight(max_inflight));
            let t = Arc::new(LoopbackTransport::new(core));
            transports.push(t.clone());
            workers.push((format!("loopback:{i}"), t));
        }
        (Membership::from_transports(workers), transports)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.slots.iter().filter(|s| s.alive.load(Ordering::SeqCst)).count()
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.slots[i].alive.load(Ordering::SeqCst)
    }

    /// In-band death report from a failed dispatch.
    pub fn mark_dead(&self, i: usize) {
        self.slots[i].alive.store(false, Ordering::SeqCst);
    }

    pub fn transport(&self, i: usize) -> &Arc<dyn Transport> {
        &self.slots[i].transport
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.slots[i].addr
    }

    /// One heartbeat round-trip; updates liveness in both directions
    /// (a dead slot that answers revives — with an empty shard cache,
    /// which is why the driver's per-job ban list exists).
    pub fn probe(&self, i: usize) -> bool {
        let ok = self.slots[i]
            .transport
            .request(&proto::heartbeat_request())
            .and_then(proto::check_reply)
            .is_ok();
        self.slots[i].alive.store(ok, Ordering::SeqCst);
        ok
    }

    /// Start the background heartbeat: every `period_ms`, probe all
    /// slots and report the alive count (the coordinator points
    /// `gauge_cb` at its `cluster_workers` gauge). No-op if `period_ms`
    /// is 0 or a heartbeat is already running.
    pub fn start_heartbeat(
        self: &Arc<Self>,
        period_ms: u64,
        gauge_cb: Arc<dyn Fn(usize) + Send + Sync>,
    ) {
        if period_ms == 0 {
            return;
        }
        let mut guard = self.hb_thread.lock().unwrap();
        if guard.is_some() {
            return;
        }
        let me = self.clone();
        let stop = self.hb_stop.clone();
        let handle = std::thread::Builder::new()
            .name("cluster-heartbeat".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    for i in 0..me.len() {
                        me.probe(i);
                    }
                    gauge_cb(me.alive_count());
                    // Sleep in small slices so Drop joins promptly.
                    let mut left = period_ms;
                    while left > 0 && !stop.load(Ordering::SeqCst) {
                        let step = left.min(25);
                        std::thread::sleep(Duration::from_millis(step));
                        left -= step;
                    }
                }
            })
            .expect("spawn heartbeat thread");
        *guard = Some(handle);
    }

    /// Stop and join the heartbeat thread, if any.
    pub fn stop_heartbeat(&self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.hb_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Membership {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.hb_thread.get_mut().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn loopback_roster_tracks_death_and_revival() {
        let (m, transports) = Membership::loopback(3, 0);
        assert_eq!((m.len(), m.alive_count()), (3, 3));
        // Kill worker 1: probe notices; the roster shrinks.
        transports[1].fail_after_requests(0);
        assert!(!m.probe(1));
        assert_eq!(m.alive_count(), 2);
        assert!(m.is_alive(0) && !m.is_alive(1) && m.is_alive(2));
        // mark_dead is the in-band path to the same state.
        m.mark_dead(2);
        assert_eq!(m.alive_count(), 1);
        // A live worker's probe revives the roster entry.
        assert!(m.probe(2));
        assert_eq!(m.alive_count(), 2);
    }

    #[test]
    fn heartbeat_feeds_the_gauge_and_stops() {
        let (m, transports) = Membership::loopback(2, 0);
        let m = Arc::new(m);
        let last = Arc::new(AtomicUsize::new(usize::MAX));
        let seen = last.clone();
        m.start_heartbeat(
            5,
            Arc::new(move |alive| seen.store(alive, Ordering::SeqCst)),
        );
        for _ in 0..100 {
            if last.load(Ordering::SeqCst) == 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(last.load(Ordering::SeqCst), 2);
        transports[0].fail_after_requests(0);
        for _ in 0..100 {
            if last.load(Ordering::SeqCst) == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(last.load(Ordering::SeqCst), 1, "heartbeat must notice the death");
        m.stop_heartbeat();
    }

    #[test]
    fn connect_to_unreachable_addr_starts_dead() {
        // Port 9 on localhost: nothing listens there in CI.
        let m = Membership::connect(&["127.0.0.1:9".to_string()]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.alive_count(), 0);
    }
}
