//! How a shard request reaches a worker. The driver only sees the
//! [`Transport`] trait, so tests and benches can swap the TCP hop for an
//! in-process loopback — including one that drops dead mid-solve to
//! simulate a `kill -9`.
//!
//! Error contract: a transport returns `Err` only for *delivery*
//! failures (connect/read/write) — the worker is presumed gone. A worker
//! that answered with a structured `ok: false` line comes back as
//! `Ok(json)`; [`super::proto::check_reply`] maps it afterwards. The
//! driver relies on this split to tell "re-dispatch the shard" from
//! "back off and retry" from "fail the job".

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::api::SolverError;
use crate::util::json::Json;

use super::worker::WorkerCore;

/// One request/reply exchange with a worker.
pub trait Transport: Send + Sync {
    fn request(&self, req: &Json) -> Result<Json, SolverError>;
}

/// Persistent newline-JSON connection to a worker address; reconnects
/// lazily after failures (same discipline as [`crate::client::Client`],
/// minus the retry policy — the cluster driver owns retries, because a
/// failed shard may have to move to a *different* worker rather than be
/// retried on the same one).
pub struct TcpTransport {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
}

impl TcpTransport {
    pub fn new(addr: impl Into<String>) -> Self {
        TcpTransport { addr: addr.into(), stream: Mutex::new(None) }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn io_err(&self, what: &str, e: std::io::Error) -> SolverError {
        SolverError::Service(format!("cluster worker {}: {what}: {e}", self.addr))
    }

    fn roundtrip(
        &self,
        s: &mut TcpStream,
        req: &Json,
        timeout: Option<Duration>,
    ) -> Result<Json, SolverError> {
        s.set_read_timeout(timeout).map_err(|e| self.io_err("set timeout", e))?;
        let mut line = req.to_string();
        line.push('\n');
        s.write_all(line.as_bytes()).map_err(|e| self.io_err("write", e))?;
        let mut reply = String::new();
        let mut r = BufReader::new(s.try_clone().map_err(|e| self.io_err("clone", e))?);
        let n = r.read_line(&mut reply).map_err(|e| self.io_err("read", e))?;
        if n == 0 {
            return Err(SolverError::Service(format!(
                "cluster worker {}: connection closed",
                self.addr
            )));
        }
        Json::parse(reply.trim()).map_err(|e| {
            SolverError::Service(format!("cluster worker {}: bad reply: {e}", self.addr))
        })
    }
}

impl Transport for TcpTransport {
    fn request(&self, req: &Json) -> Result<Json, SolverError> {
        let mut guard = self.stream.lock().unwrap();
        if guard.is_none() {
            let s = TcpStream::connect(&self.addr).map_err(|e| self.io_err("connect", e))?;
            *guard = Some(s);
        }
        // The round's deadline doubles as the socket read timeout, so a
        // hung worker surfaces as a delivery failure within budget.
        let timeout = req
            .get("deadline_ms")
            .and_then(Json::as_f64)
            .map(|ms| Duration::from_millis((ms as u64).max(1)));
        let result =
            self.roundtrip(guard.as_mut().expect("stream populated above"), req, timeout);
        if result.is_err() {
            *guard = None; // force a fresh connection on the next attempt
        }
        result
    }
}

/// In-process transport straight into a [`WorkerCore`] — what the
/// loopback tests and benches use. [`LoopbackTransport::fail_after_requests`]
/// arms a failure point: once the budget is spent every request fails
/// like a severed connection, forever — the `kill -9` a test can
/// schedule mid-solve.
pub struct LoopbackTransport {
    core: Arc<WorkerCore>,
    remaining: AtomicU64,
}

impl LoopbackTransport {
    pub fn new(core: Arc<WorkerCore>) -> Self {
        LoopbackTransport { core, remaining: AtomicU64::new(u64::MAX) }
    }

    /// Serve `n` more requests, then fail every one after (u64::MAX =
    /// never fail, the default).
    pub fn fail_after_requests(&self, n: u64) {
        self.remaining.store(n, Ordering::SeqCst);
    }
}

impl Transport for LoopbackTransport {
    fn request(&self, req: &Json) -> Result<Json, SolverError> {
        loop {
            let left = self.remaining.load(Ordering::SeqCst);
            if left == 0 {
                return Err(SolverError::Service("cluster worker loopback: killed".into()));
            }
            if left == u64::MAX {
                break; // unlimited; skip the decrement
            }
            if self
                .remaining
                .compare_exchange(left, left - 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                break;
            }
        }
        // Round-trip through the wire encoding so loopback exercises the
        // same f32 -> JSON -> f32 path TCP does (bit-identity included).
        let wire = req.to_string();
        let req = Json::parse(&wire).expect("request re-parses");
        let reply = self.core.handle_request(&req).to_string();
        Ok(Json::parse(&reply).expect("reply re-parses"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_round_trips_and_kills() {
        let t = LoopbackTransport::new(Arc::new(WorkerCore::new("lb")));
        let ping = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        assert!(t.request(&ping).is_ok());
        t.fail_after_requests(1);
        assert!(t.request(&ping).is_ok(), "one request left in the budget");
        assert!(matches!(t.request(&ping), Err(SolverError::Service(_))), "killed");
        assert!(matches!(t.request(&ping), Err(SolverError::Service(_))), "stays dead");
    }

    #[test]
    fn tcp_transport_reaches_a_worker_server_and_survives_restart() {
        use super::super::worker::WorkerServer;
        let core = Arc::new(WorkerCore::new("w-t"));
        let srv = WorkerServer::bind(core.clone(), 0).unwrap();
        let t = TcpTransport::new(srv.addr().to_string());
        let ping = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        let r = t.request(&ping).unwrap();
        assert_eq!(r.get("pong").unwrap().as_str(), Some("pong"));
        srv.stop();
        // Server gone: delivery failure, not a structured error.
        let mut saw_err = false;
        for _ in 0..3 {
            if t.request(&ping).is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "requests to a stopped server must fail");
    }
}
