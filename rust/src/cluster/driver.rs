//! The coordinator side of a cluster solve: [`ClusterDriver`] mirrors
//! the in-process block schedulers sweep-for-sweep, farming the
//! per-block inner sweeps out to workers and keeping *all* global solver
//! state — iterate, residual, history, and the stop ladder — locally.
//!
//! Bit-identity: for a fixed `(seed, shards)` the result equals
//! [`crate::parallel::solve_kaczmarz_par`] / [`crate::parallel::solve_bak_par`]
//! with `threads = shards`, because every numeric step happens either
//! (a) on the worker with the same local data, operation sequence, and
//! `(seed, sweep * nb + shard)` RNG stream the in-process block uses, or
//! (b) here, verbatim from the in-process scheduler (f64 mass-weighted
//! merge in block order, residual + stop ladder). Worker identity
//! appears in neither, so a shard re-dispatched after a worker death
//! continues the exact same sequence on its new host.
//!
//! Failure handling composes with the robust layer instead of
//! reinventing it: per-round deadlines come from the job's
//! [`crate::robust::CancelToken`]; `overloaded` workers feed the
//! [`crate::client::RetryPolicy`] backoff; a dead worker gets its shards
//! re-dispatched (with data) to survivors and the outcome surfaces
//! `resharded = true`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::api::{SolverError, SolverKind};
use crate::client::RetryPolicy;
use crate::coordinator::metrics::Metrics;
use crate::linalg::{blas1, Mat};
use crate::obs::{shard_span_name, TraceCtx};
use crate::parallel::stream_seed;
use crate::robust::CancelToken;
use crate::solver::{ColumnOrder, SolveOptions, SolveReport, StopReason};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::membership::Membership;
use super::planner::{self, ShardAxis, ShardPlan};
use super::proto::{self, ShardData, ShardRound};
use super::ClusterConfig;

/// What a cluster solve hands back to the coordinator, beyond the
/// report itself.
pub struct ClusterSolveOutcome {
    pub report: SolveReport,
    /// True when any shard had to move to a surviving worker mid-solve.
    pub resharded: bool,
    /// Global sync rounds completed (== sweeps dispatched to workers).
    pub sync_rounds: u64,
}

/// Per-job dispatch state: which worker owns which shard, which workers
/// this job has written off, and what each worker has cached.
struct JobState {
    job: String,
    /// shard -> membership slot.
    assignment: Vec<usize>,
    /// Per-slot, per-job ban: a worker that failed this job never gets
    /// its shards back, even if the global heartbeat revives it — its
    /// shard cache died with it.
    banned: Vec<bool>,
    /// `data_present[slot][shard]`: the worker holds that shard's data.
    data_present: Vec<Vec<bool>>,
    /// Round-robin cursor for (re)assignment.
    cursor: usize,
    resharded: bool,
    /// Per-shard `(first_start_ns, last_end_ns)` over all rounds, for
    /// the trace's per-shard span children.
    spans: Vec<Option<(u64, u64)>>,
}

/// Coordinator-side merge driver for distributed shard solves.
pub struct ClusterDriver {
    membership: Arc<Membership>,
    policy: RetryPolicy,
    heartbeat_ms: u64,
    metrics: OnceLock<Arc<Metrics>>,
    job_counter: AtomicU64,
}

impl ClusterDriver {
    /// Driver over an explicit roster (tests/benches).
    pub fn new(membership: Arc<Membership>) -> Self {
        ClusterDriver {
            membership,
            policy: RetryPolicy::default(),
            heartbeat_ms: 0,
            metrics: OnceLock::new(),
            job_counter: AtomicU64::new(0),
        }
    }

    /// Driver over TCP workers from a [`ClusterConfig`] (join-probes
    /// each address; unreachable workers start dead).
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let mut d = Self::new(Arc::new(Membership::connect(&cfg.workers)));
        d.heartbeat_ms = cfg.heartbeat_ms;
        d
    }

    /// Replace the overload backoff policy.
    pub fn with_policy(mut self, policy: RetryPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }

    /// Attach the coordinator's metrics: seeds the `cluster_workers`
    /// gauge and starts the background heartbeat (if configured) to keep
    /// it honest between solves.
    pub fn attach_metrics(&self, metrics: Arc<Metrics>) {
        metrics.cluster_workers.store(self.membership.alive_count() as u64, Ordering::Relaxed);
        if self.metrics.set(metrics.clone()).is_ok() && self.heartbeat_ms > 0 {
            let gauge = metrics;
            self.membership.start_heartbeat(
                self.heartbeat_ms,
                Arc::new(move |alive| {
                    gauge.cluster_workers.store(alive as u64, Ordering::Relaxed);
                }),
            );
        }
    }

    fn metric(&self, f: impl Fn(&Metrics)) {
        if let Some(m) = self.metrics.get() {
            f(m);
        }
    }

    /// Run one sharded solve. `trace` is the open parent span (the
    /// coordinator's `solve` span) to hang per-shard children off.
    pub fn solve(
        &self,
        kind: SolverKind,
        x: &Mat,
        y: &[f32],
        opts: &SolveOptions,
        trace: Option<(&TraceCtx, usize)>,
    ) -> Result<ClusterSolveOutcome, SolverError> {
        let (obs, vars) = (x.rows(), x.cols());
        if y.len() != obs {
            return Err(SolverError::Shape(format!(
                "y has {} entries for {obs} observations",
                y.len()
            )));
        }
        let shards = opts.threads.max(1);
        let plan = ShardPlan::plan(kind, obs, vars, shards).ok_or_else(|| {
            SolverError::Unsupported(format!(
                "cluster: backend {} does not support sharding",
                kind.as_str()
            ))
        })?;
        let mut state = self.new_job(plan.nb())?;
        let result = match kind {
            SolverKind::KaczmarzPar => {
                self.solve_kaczmarz(&plan, x, y, opts, trace.map(|(c, _)| c), &mut state)
            }
            SolverKind::BakPar => {
                self.solve_bak(&plan, x, y, opts, trace.map(|(c, _)| c), &mut state)
            }
            _ => unreachable!("plan() only exists for the sharding pair"),
        };
        self.release(&state);
        if let Some((ctx, parent)) = trace {
            for (b, span) in state.spans.iter().enumerate() {
                if let Some((start_ns, end_ns)) = span {
                    ctx.record_ns(shard_span_name(b), *start_ns, *end_ns, Some(parent));
                }
            }
        }
        result.map(|(report, sync_rounds)| ClusterSolveOutcome {
            report,
            resharded: state.resharded,
            sync_rounds,
        })
    }

    fn new_job(&self, nb: usize) -> Result<JobState, SolverError> {
        let slots = self.membership.len();
        let mut state = JobState {
            job: format!("cluster-{}", self.job_counter.fetch_add(1, Ordering::Relaxed)),
            assignment: Vec::with_capacity(nb),
            banned: vec![false; slots],
            data_present: vec![vec![false; nb]; slots],
            cursor: 0,
            resharded: false,
            spans: vec![None; nb],
        };
        for _ in 0..nb {
            let slot = self.next_slot(&mut state).ok_or_else(|| {
                SolverError::Service("cluster: no alive workers".to_string())
            })?;
            state.assignment.push(slot);
        }
        Ok(state)
    }

    /// Next alive, non-banned slot, round-robin from the cursor.
    fn next_slot(&self, state: &mut JobState) -> Option<usize> {
        let n = self.membership.len();
        for step in 0..n {
            let slot = (state.cursor + step) % n;
            if !state.banned[slot] && self.membership.is_alive(slot) {
                state.cursor = slot + 1;
                return Some(slot);
            }
        }
        None
    }

    #[allow(clippy::too_many_arguments)]
    fn build_request(
        &self,
        state: &JobState,
        plan: &ShardPlan,
        x: &Mat,
        y: &[f32],
        kind: SolverKind,
        opts: &SolveOptions,
        sweep: usize,
        sync: &[f32],
        b: usize,
        with_data: bool,
    ) -> Json {
        let round = ShardRound {
            job: &state.job,
            kind,
            shard: b,
            nb: plan.nb(),
            sweep,
            seed: opts.seed,
            shuffled: opts.order == ColumnOrder::Shuffled,
            sync,
            deadline_ms: opts.cancel.remaining_ms(),
        };
        if !with_data {
            return proto::shard_solve_request(&round, None);
        }
        let range = &plan.ranges[b];
        let sub = plan.extract(x, b);
        let y_slice: &[f32] = match plan.axis {
            ShardAxis::Rows => &y[range.clone()],
            ShardAxis::Cols => &[],
        };
        let data = ShardData {
            start: range.start,
            rows: sub.rows(),
            cols: sub.cols(),
            x: sub.as_slice(),
            y: y_slice,
        };
        proto::shard_solve_request(&round, Some(&data))
    }

    /// One request with the retry-on-`overloaded` backoff; every other
    /// error surfaces to the caller for the reshard decision.
    fn call_with_retry(
        &self,
        slot: usize,
        req: &Json,
        cancel: &CancelToken,
        stream: u64,
    ) -> Result<Json, SolverError> {
        self.metric(|m| {
            m.shards_dispatched.fetch_add(1, Ordering::Relaxed);
        });
        let mut rng = Rng::seed(stream_seed(self.policy.jitter_seed, stream));
        let mut attempt: u32 = 0;
        loop {
            match self
                .membership
                .transport(slot)
                .request(req)
                .and_then(proto::check_reply)
            {
                Ok(r) => return Ok(r),
                Err(SolverError::Overloaded { retry_after_ms }) => {
                    if attempt >= self.policy.max_retries || cancel.is_cancelled() {
                        return Err(SolverError::Overloaded { retry_after_ms });
                    }
                    attempt += 1;
                    let ms = self.policy.backoff_ms(attempt, retry_after_ms, &mut rng);
                    self.metric(|m| {
                        m.retries_attempted.fetch_add(1, Ordering::Relaxed);
                    });
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Dispatch one sync round for every shard (concurrently), then
    /// re-dispatch any failed shard to a surviving worker. Returns the
    /// per-shard replies in shard order.
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &self,
        state: &mut JobState,
        plan: &ShardPlan,
        x: &Mat,
        y: &[f32],
        kind: SolverKind,
        opts: &SolveOptions,
        sweep: usize,
        sync: &[f32],
        trace: Option<&TraceCtx>,
    ) -> Result<Vec<Json>, SolverError> {
        let nb = plan.nb();
        let assignment = state.assignment.clone();
        let mut reqs = Vec::with_capacity(nb);
        for (b, &slot) in assignment.iter().enumerate() {
            let with_data = !state.data_present[slot][b];
            reqs.push(self.build_request(state, plan, x, y, kind, opts, sweep, sync, b, with_data));
        }
        // Phase 1 — concurrent dispatch, one thread per shard (the
        // cluster analogue of par_map_chunks).
        let results: Vec<(Result<Json, SolverError>, u64, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..nb)
                .map(|b| {
                    let req = &reqs[b];
                    let slot = assignment[b];
                    let cancel = &opts.cancel;
                    let stream = (sweep * nb + b) as u64;
                    s.spawn(move || {
                        let start_ns = trace.map(|c| c.now_ns()).unwrap_or(0);
                        let r = self.call_with_retry(slot, req, cancel, stream);
                        let end_ns = trace.map(|c| c.now_ns()).unwrap_or(0);
                        (r, start_ns, end_ns)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard dispatch thread")).collect()
        });

        // Bookkeeping + phase 2 — sequential re-dispatch of failures.
        let mut replies: Vec<Json> = Vec::with_capacity(nb);
        for (b, (result, start_ns, end_ns)) in results.into_iter().enumerate() {
            state.spans[b] = match state.spans[b] {
                None => Some((start_ns, end_ns)),
                Some((first, _)) => Some((first, end_ns)),
            };
            match result {
                Ok(reply) => {
                    state.data_present[assignment[b]][b] = true;
                    replies.push(reply);
                }
                Err(e @ (SolverError::InvalidInput(_) | SolverError::Unsupported(_))) => {
                    // The worker understood us and said no — moving the
                    // shard elsewhere cannot help.
                    return Err(e);
                }
                Err(e) => {
                    crate::debug!(
                        "cluster",
                        "shard {b} failed on worker {} ({e}); resharding",
                        self.membership.addr(assignment[b])
                    );
                    replies.push(self.reshard(state, plan, x, y, kind, opts, sweep, sync, b)?);
                }
            }
        }
        self.metric(|m| {
            m.sync_rounds.fetch_add(1, Ordering::Relaxed);
        });
        Ok(replies)
    }

    /// Move shard `b` off its (now banned) worker onto the next
    /// survivor, resending the shard data; walks the roster until a
    /// survivor answers or none are left. The round parameters are
    /// identical to the failed dispatch — the RNG stream is keyed by
    /// `(seed, sweep, shard)`, not by worker — so the retried round
    /// produces the exact bytes the dead worker would have.
    #[allow(clippy::too_many_arguments)]
    fn reshard(
        &self,
        state: &mut JobState,
        plan: &ShardPlan,
        x: &Mat,
        y: &[f32],
        kind: SolverKind,
        opts: &SolveOptions,
        sweep: usize,
        sync: &[f32],
        b: usize,
    ) -> Result<Json, SolverError> {
        loop {
            let dead = state.assignment[b];
            if !state.banned[dead] {
                state.banned[dead] = true;
                self.membership.mark_dead(dead);
                self.metric(|m| {
                    m.reshards.fetch_add(1, Ordering::Relaxed);
                    m.cluster_workers.store(self.membership.alive_count() as u64, Ordering::Relaxed);
                });
                state.resharded = true;
            }
            let Some(slot) = self.next_slot(state) else {
                return Err(SolverError::Service(
                    "cluster: no alive workers left after reshard".to_string(),
                ));
            };
            state.assignment[b] = slot;
            // Warm start: `sync` already carries the last merged global
            // state, and the replacement worker needs the data again.
            let req = self.build_request(state, plan, x, y, kind, opts, sweep, sync, b, true);
            let stream = (sweep * plan.nb() + b) as u64;
            match self.call_with_retry(slot, &req, &opts.cancel, stream) {
                Ok(reply) => {
                    state.data_present[slot][b] = true;
                    crate::debug!(
                        "cluster",
                        "shard {b} re-dispatched to worker {}",
                        self.membership.addr(slot)
                    );
                    return Ok(reply);
                }
                Err(e @ (SolverError::InvalidInput(_) | SolverError::Unsupported(_))) => {
                    return Err(e);
                }
                Err(_) => continue, // this survivor died too; ban and move on
            }
        }
    }

    /// Best-effort end-of-job cache release on every worker that holds
    /// shard data for this job.
    fn release(&self, state: &JobState) {
        let req = proto::release_request(&state.job);
        for slot in 0..self.membership.len() {
            if state.data_present[slot].iter().any(|&d| d)
                && !state.banned[slot]
                && self.membership.is_alive(slot)
            {
                let _ = self.membership.transport(slot).request(&req);
            }
        }
    }

    /// Distributed `kaczmarz_par`: the scheduler below is
    /// `kaczmarz_par_generic` with the per-block closure replaced by a
    /// `shard_solve` round trip (see `parallel/solvers.rs`).
    fn solve_kaczmarz(
        &self,
        plan: &ShardPlan,
        x: &Mat,
        y: &[f32],
        opts: &SolveOptions,
        trace: Option<&TraceCtx>,
        state: &mut JobState,
    ) -> Result<(SolveReport, u64), SolverError> {
        let vars = x.cols();
        let row_norms_sq = planner::row_norms_sq(x);
        let total: f64 = row_norms_sq.iter().map(|&v| v as f64).sum();
        let y_norm_sq = blas1::sum_sq_f64(y);
        if total == 0.0 {
            // All-zero matrix: same trivial report as in-process, no
            // rounds dispatched.
            let stop =
                if y_norm_sq == 0.0 { StopReason::Converged } else { StopReason::Stalled };
            return Ok((
                SolveReport {
                    a: vec![0.0f32; vars],
                    e: y.to_vec(),
                    history: vec![y_norm_sq],
                    y_norm_sq,
                    sweeps: 0,
                    stop,
                },
                0,
            ));
        }
        // Block masses over the global row norms — the merge weights.
        let masses: Vec<f64> = plan
            .ranges
            .iter()
            .map(|r| row_norms_sq[r.clone()].iter().map(|&v| v as f64).sum())
            .collect();

        let tol_sq = opts.tol * opts.tol * y_norm_sq;
        let mut a = vec![0.0f32; vars];
        let mut history = Vec::new();
        let mut stop = StopReason::MaxSweeps;
        let mut sweeps = 0;
        let mut sync_rounds = 0u64;
        let mut prev_r2 = f64::INFINITY;
        let t0 = std::time::Instant::now();

        for sweep in 0..opts.max_sweeps {
            let replies = self.run_round(
                state,
                plan,
                x,
                y,
                SolverKind::KaczmarzPar,
                opts,
                sweep,
                &a,
                trace,
            )?;
            let mut iterates = Vec::with_capacity(replies.len());
            for (b, reply) in replies.iter().enumerate() {
                let ab = reply.get("ab").and_then(proto::json_to_f32s).ok_or_else(|| {
                    bad_reply(b, "missing \"ab\"")
                })?;
                if ab.len() != vars {
                    return Err(bad_reply(b, "wrong-length \"ab\""));
                }
                iterates.push(ab);
            }

            // Averaging sync — f64 accumulation in block order, verbatim.
            for (j, aj) in a.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for (mass, ab) in masses.iter().zip(&iterates) {
                    acc += (mass / total) * ab[j] as f64;
                }
                *aj = acc as f32;
            }
            sync_rounds += 1;

            sweeps = sweep + 1;
            let e = crate::linalg::residual(x, y, &a);
            let r2 = blas1::sum_sq_f64(&e);
            history.push(r2);
            opts.probe.observe(sweeps, r2, t0);
            if !r2.is_finite() {
                stop = StopReason::Breakdown;
                break;
            }
            opts.probe.observe_state(sweeps, &a, &e, r2);
            if opts.cancel.is_cancelled() {
                stop = StopReason::Cancelled;
                break;
            }
            if opts.tol > 0.0 && r2 <= tol_sq {
                stop = StopReason::Converged;
                break;
            }
            if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                stop = StopReason::Stalled;
                break;
            }
            prev_r2 = r2;
        }
        let e = crate::linalg::residual(x, y, &a);
        Ok((SolveReport { a, e, history, y_norm_sq, sweeps, stop }, sync_rounds))
    }

    /// Distributed `bak_par`: `bak_par_generic`'s scheduler with the
    /// per-block closure replaced by a `shard_solve` round trip.
    fn solve_bak(
        &self,
        plan: &ShardPlan,
        x: &Mat,
        y: &[f32],
        opts: &SolveOptions,
        trace: Option<&TraceCtx>,
        state: &mut JobState,
    ) -> Result<(SolveReport, u64), SolverError> {
        let (obs, vars) = (x.rows(), x.cols());
        let nb = plan.nb();
        let y_norm_sq = blas1::sum_sq_f64(y);
        let tol_sq = opts.tol * opts.tol * y_norm_sq;

        let mut a = vec![0.0f32; vars];
        let mut e = y.to_vec();
        let mut history = Vec::with_capacity(opts.max_sweeps.min(1024));
        let mut stop = StopReason::MaxSweeps;
        let mut sweeps = 0;
        let mut sync_rounds = 0u64;
        let mut prev_r2 = f64::INFINITY;
        let t0 = std::time::Instant::now();

        for sweep in 0..opts.max_sweeps {
            let replies =
                self.run_round(state, plan, x, y, SolverKind::BakPar, opts, sweep, &e, trace)?;
            let mut results: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(nb);
            for (b, reply) in replies.iter().enumerate() {
                let da = reply.get("da").and_then(proto::json_to_f32s).ok_or_else(|| {
                    bad_reply(b, "missing \"da\"")
                })?;
                let e_loc =
                    reply.get("e_loc").and_then(proto::json_to_f32s).ok_or_else(|| {
                        bad_reply(b, "missing \"e_loc\"")
                    })?;
                if da.len() != plan.ranges[b].len() || e_loc.len() != obs {
                    return Err(bad_reply(b, "wrong-length \"da\"/\"e_loc\""));
                }
                results.push((da, e_loc));
            }

            // Sync, verbatim from bak_par_generic: additive coefficient
            // merge (disjoint column ownership) and the residual fold
            // e' = Σ_b e_b − (B−1)e in f64, block order per element.
            if nb == 1 {
                let (da, e_loc) = results.pop().expect("one shard");
                for (k, &d) in da.iter().enumerate() {
                    a[k] += d;
                }
                e = e_loc;
            } else {
                for (range, (da, _)) in plan.ranges.iter().zip(&results) {
                    for (k, &d) in da.iter().enumerate() {
                        a[range.start + k] += d;
                    }
                }
                let coeff = (nb - 1) as f64;
                for (r, w) in e.iter_mut().enumerate() {
                    let mut acc = -coeff * (*w as f64);
                    for (_, e_loc) in &results {
                        acc += e_loc[r] as f64;
                    }
                    *w = acc as f32;
                }
            }
            sync_rounds += 1;

            sweeps = sweep + 1;
            let check_now = opts.check_every != 0 && sweeps % opts.check_every == 0;
            if check_now || sweeps == opts.max_sweeps {
                let r2 = blas1::sum_sq_f64(&e);
                history.push(r2);
                opts.probe.observe(sweeps, r2, t0);
                if !r2.is_finite() {
                    stop = StopReason::Breakdown;
                    break;
                }
                opts.probe.observe_state(sweeps, &a, &e, r2);
                if opts.cancel.is_cancelled() {
                    stop = StopReason::Cancelled;
                    break;
                }
                if opts.tol > 0.0 && r2 <= tol_sq {
                    stop = StopReason::Converged;
                    break;
                }
                if r2 >= prev_r2 * (1.0 - 1e-9) && sweeps > 1 {
                    stop = StopReason::Stalled;
                    break;
                }
                prev_r2 = r2;
            }
        }
        Ok((SolveReport { a, e, history, y_norm_sq, sweeps, stop }, sync_rounds))
    }
}

fn bad_reply(shard: usize, what: &str) -> SolverError {
    SolverError::Backend {
        backend: "cluster-worker".into(),
        reason: format!("shard {shard} reply: {what}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{solve_bak_par, solve_kaczmarz_par};

    fn planted(seed: u64, obs: usize, vars: usize) -> (Mat, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let x = Mat::randn(&mut rng, obs, vars);
        let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
        let y = x.matvec(&a_true);
        (x, y)
    }

    fn assert_reports_identical(cluster: &SolveReport, local: &SolveReport) {
        assert_eq!(cluster.a, local.a, "coefficients must match bit-for-bit");
        assert_eq!(cluster.e, local.e, "residuals must match bit-for-bit");
        assert_eq!(cluster.history, local.history, "history must match");
        assert_eq!(cluster.sweeps, local.sweeps);
        assert_eq!(cluster.stop, local.stop);
        assert_eq!(cluster.y_norm_sq, local.y_norm_sq);
    }

    #[test]
    fn kaczmarz_two_workers_bit_identical_to_in_process() {
        let (x, y) = planted(11, 48, 6);
        let mut opts = SolveOptions::default();
        opts.threads = 3; // = shards
        opts.max_sweeps = 20;
        let (membership, _t) = Membership::loopback(2, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let out = driver.solve(SolverKind::KaczmarzPar, &x, &y, &opts, None).unwrap();
        let local = solve_kaczmarz_par(&x, &y, &opts);
        assert_reports_identical(&out.report, &local);
        assert!(!out.resharded);
        assert_eq!(out.sync_rounds as usize, local.sweeps);
    }

    #[test]
    fn bak_shuffled_bit_identical_to_in_process() {
        let (x, y) = planted(12, 40, 8);
        let mut opts = SolveOptions::default();
        opts.threads = 4;
        opts.order = ColumnOrder::Shuffled;
        opts.max_sweeps = 30;
        let (membership, _t) = Membership::loopback(3, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let out = driver.solve(SolverKind::BakPar, &x, &y, &opts, None).unwrap();
        let local = solve_bak_par(&x, &y, &opts);
        assert_reports_identical(&out.report, &local);
        assert!(!out.resharded);
    }

    #[test]
    fn worker_death_reshards_and_preserves_bit_identity() {
        let (x, y) = planted(13, 36, 5);
        let mut opts = SolveOptions::default();
        opts.threads = 2;
        opts.max_sweeps = 25;
        let (membership, transports) = Membership::loopback(2, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        // Worker 1 serves a few rounds, then dies mid-solve.
        transports[1].fail_after_requests(3);
        let out = driver.solve(SolverKind::KaczmarzPar, &x, &y, &opts, None).unwrap();
        assert!(out.resharded, "the death must surface as a reshard");
        assert_eq!(driver.membership().alive_count(), 1);
        let local = solve_kaczmarz_par(&x, &y, &opts);
        assert_reports_identical(&out.report, &local);
    }

    #[test]
    fn all_workers_dead_is_a_service_error() {
        let (x, y) = planted(14, 12, 3);
        let opts = SolveOptions::default();
        let (membership, transports) = Membership::loopback(2, 0);
        for t in &transports {
            t.fail_after_requests(0);
        }
        let driver = ClusterDriver::new(Arc::new(membership));
        let err = driver.solve(SolverKind::KaczmarzPar, &x, &y, &opts, None).unwrap_err();
        assert!(matches!(err, SolverError::Service(_)), "{err:?}");
    }

    #[test]
    fn non_sharding_kind_is_unsupported() {
        let (x, y) = planted(15, 10, 3);
        let (membership, _t) = Membership::loopback(1, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let err = driver
            .solve(SolverKind::Bak, &x, &y, &SolveOptions::default(), None)
            .unwrap_err();
        assert!(matches!(err, SolverError::Unsupported(_)), "{err:?}");
    }

    #[test]
    fn trace_records_per_shard_spans() {
        let (x, y) = planted(16, 24, 4);
        let mut opts = SolveOptions::default();
        opts.threads = 2;
        opts.max_sweeps = 5;
        let (membership, _t) = Membership::loopback(2, 0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let ctx = TraceCtx::fresh();
        let parent = ctx.begin("solve", None);
        driver.solve(SolverKind::KaczmarzPar, &x, &y, &opts, Some((&ctx, parent))).unwrap();
        ctx.end(parent);
        let spans = ctx.spans();
        let shard_spans: Vec<_> =
            spans.iter().filter(|s| s.name.starts_with("shard")).collect();
        assert_eq!(shard_spans.len(), 2, "one child span per shard");
        for s in shard_spans {
            assert_eq!(s.parent, Some(parent));
            assert!(s.end_ns >= s.start_ns);
        }
    }

    #[test]
    fn all_zero_matrix_takes_the_trivial_path_without_dispatch() {
        let x = Mat::zeros(6, 3);
        let y = vec![1.0f32; 6];
        let (membership, transports) = Membership::loopback(1, 0);
        // A dead worker proves nothing is dispatched on this path.
        transports[0].fail_after_requests(0);
        let driver = ClusterDriver::new(Arc::new(membership));
        let out = driver
            .solve(SolverKind::KaczmarzPar, &x, &y, &SolveOptions::default(), None)
            .unwrap();
        assert_eq!(out.report.stop, StopReason::Stalled);
        assert_eq!(out.report.sweeps, 0);
        assert_eq!(out.sync_rounds, 0);
        let local = solve_kaczmarz_par(&x, &y, &SolveOptions::default());
        assert_reports_identical(&out.report, &local);
    }
}
