//! Distributed shard cluster: multi-node row-partitioned solves over an
//! additive extension of wire protocol v1 (v1.2 — see `PROTOCOL.md`).
//!
//! The paper's core rationale — each inner step touches one dimension of
//! `X` — is what makes the block-parallel pair distributable: between two
//! sync points the per-block iterates of `kaczmarz_par` (row blocks) and
//! `bak_par` (column blocks) are fully independent, so the blocks can
//! live in *other processes* and only the O(obs)/O(vars) sync vectors
//! cross the wire. This module runs exactly that scheme:
//!
//! * [`planner`] — derives the shard plan from `(shape, shards)` via the
//!   same [`crate::parallel::partition_ranges`] the in-process solvers
//!   use, and extracts each shard's column-major submatrix.
//! * [`proto`] — the v1.2 message vocabulary (`join`, `heartbeat`,
//!   `shard_solve`) as JSON builders/parsers; floats survive the trip
//!   bit-exactly (f32 → f64 → shortest-roundtrip decimal → f64 → f32).
//! * [`transport`] — how a shard request reaches a worker: a persistent
//!   newline-JSON [`transport::TcpTransport`], or the in-process
//!   [`transport::LoopbackTransport`] used by tests and benches (which
//!   can also simulate a `kill -9` mid-solve).
//! * [`worker`] — [`worker::WorkerCore`] answers the v1.2 commands
//!   (caching shard data per `(job, shard)`), and
//!   [`worker::WorkerServer`] serves it over TCP for
//!   `solvebak serve-worker`.
//! * [`membership`] — the coordinator's view of the worker set: per-slot
//!   liveness, heartbeat probing, and dead-worker marking.
//! * [`driver`] — [`driver::ClusterDriver`] mirrors the in-process
//!   schedulers sweep-for-sweep: it keeps *all* global solver state
//!   (iterate, residual, history, stop ladder) and only farms out the
//!   per-block inner sweeps, merging with the same f64 mass-weighted
//!   fold in block order. For a fixed `(seed, shards)` the result is
//!   bit-identical to [`crate::parallel::solve_kaczmarz_par`] /
//!   [`crate::parallel::solve_bak_par`] with `threads = shards` — no
//!   matter how many workers serve the shards, or whether a shard was
//!   re-dispatched after a worker died mid-solve.
//!
//! Failure composition (nothing here duplicates the robust layer):
//! per-shard deadlines derive from the job's
//! [`crate::robust::CancelToken`]; a worker answering `overloaded` feeds
//! the same [`crate::client::RetryPolicy`] backoff the TCP client uses;
//! a transport failure marks the worker dead and re-dispatches its
//! shards to survivors, warm-started from the last synced iterate, and
//! the outcome surfaces `"resharded": true`.

pub mod driver;
pub mod membership;
pub mod planner;
pub mod proto;
pub mod transport;
pub mod worker;

pub use driver::{ClusterDriver, ClusterSolveOutcome};
pub use membership::Membership;
pub use transport::{LoopbackTransport, TcpTransport, Transport};
pub use worker::{WorkerCore, WorkerServer};

/// Cluster knobs carried by
/// [`crate::coordinator::CoordinatorConfig::cluster`] (None = the
/// coordinator solves everything in-process, exactly as before).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClusterConfig {
    /// Worker addresses (`host:port`), e.g. from `--workers-addrs`.
    pub workers: Vec<String>,
    /// Shard count per solve. `None` derives it from the request's
    /// `threads` knob — the shard count plays exactly the role
    /// `SolveOptions::threads` plays in-process, which is what makes the
    /// cluster result bit-identical to the threaded solver at equal
    /// `(seed, shards)`.
    pub shards: Option<usize>,
    /// Liveness probe period for the membership heartbeat thread; 0
    /// disables the background probe (worker loss is then detected
    /// in-band, by the failed shard dispatch itself).
    pub heartbeat_ms: u64,
}
