//! The coordinator behind its TCP front-end: starts the server on an
//! ephemeral localhost port, drives it from several concurrent JSON
//! clients, and shuts it down over the wire — the full network serving
//! path of `solvebak serve-tcp`.
//!
//! ```sh
//! cargo run --release --example network_service
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use solvebak::coordinator::server::Server;
use solvebak::coordinator::{Coordinator, CoordinatorConfig};
use solvebak::util::json::Json;
use solvebak::util::rng::Rng;

fn main() {
    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers: 2,
        artifact_dir: Some("artifacts".into()),
        ..CoordinatorConfig::default()
    }));
    let server = Server::bind(coord.clone(), 0).expect("bind");
    let addr = server.addr();
    println!("server listening on {addr}");

    // Three concurrent clients, each solving planted systems over the wire.
    let clients: Vec<_> = (0..3u64)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::seed(300 + c);
                let stream = TcpStream::connect(addr).expect("connect");
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                for i in 0..5 {
                    // Random 32x4 system with planted coefficients.
                    let obs = 32;
                    let vars = 4;
                    let x: Vec<f32> =
                        (0..obs * vars).map(|_| rng.normal_f32()).collect();
                    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
                    let y: Vec<f32> = (0..obs)
                        .map(|row| {
                            (0..vars).map(|j| x[row * vars + j] * a_true[j]).sum()
                        })
                        .collect();
                    let req = format!(
                        r#"{{"id": {}, "backend": "bak", "obs": {obs}, "vars": {vars}, "x": [{}], "y": [{}], "sweeps": 300, "tol": 1e-6}}"#,
                        c * 100 + i,
                        x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(","),
                        y.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(","),
                    );
                    w.write_all(req.as_bytes()).unwrap();
                    w.write_all(b"\n").unwrap();
                    let mut resp = String::new();
                    r.read_line(&mut resp).unwrap();
                    let j = Json::parse(resp.trim()).expect("json");
                    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
                    let a = j.get("a").unwrap().items();
                    for (k, want) in a_true.iter().enumerate() {
                        let got = a[k].as_f64().unwrap() as f32;
                        assert!(
                            (got - want).abs() < 1e-2,
                            "client {c} req {i}: a[{k}] {got} vs {want}"
                        );
                    }
                }
                println!("client {c}: 5/5 solves verified over TCP");
            })
        })
        .collect();
    for h in clients {
        h.join().unwrap();
    }

    // Metrics + shutdown over the wire.
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    println!("metrics: {}", resp.trim());
    w.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
    resp.clear();
    r.read_line(&mut resp).unwrap();
    println!("shutdown ack: {}", resp.trim());
    server.stop();
    println!("done.");
}
