//! END-TO-END DRIVER (see DESIGN.md §End-to-end driver).
//!
//! A realistic tall regression workload — 200k observations x 512
//! features, planted coefficients + noise — solved through EVERY layer of
//! the stack:
//!
//!   1. QR baseline (the "LAPACK" comparator),
//!   2. native SolveBak (Algorithm 1),
//!   3. native threaded SolveBakP (Algorithm 2),
//!   4. the coordinator service routing to the PJRT engine executing the
//!      AOT-compiled L2 graph (Pallas kernel inside) on a shape bucket.
//!
//! It logs the per-sweep residual curve (the "loss curve"), verifies all
//! four solutions agree, and prints a latency/throughput/allocations
//! table. The run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example tall_regression [-- --obs 200000 --vars 512]
//! ```

use std::sync::Arc;

use solvebak::baselines::qr::lstsq_qr;
use solvebak::cli::Args;
use solvebak::coordinator::{Backend, Coordinator, CoordinatorConfig, SolveRequest};
use solvebak::linalg::Mat;
use solvebak::solver::{solve_bak, solve_bakp, SolveOptions};
use solvebak::util::rng::Rng;
use solvebak::util::stats::{mape, rel_l2};
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let obs = args.get_usize("obs", 200_000).unwrap();
    let vars = args.get_usize("vars", 512).unwrap();
    let noise = args.get_f64("noise", 0.01).unwrap() as f32;
    let seed = args.get_u64("seed", 4242).unwrap();

    println!("=== tall_regression end-to-end driver ===");
    println!("workload: {obs} x {vars} (tall), noise sigma = {noise}, seed = {seed}");
    let mut rng = Rng::seed(seed);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let mut y = x.matvec(&a_true);
    for v in y.iter_mut() {
        *v += noise * rng.normal_f32();
    }
    println!("matrix: {:.1} MiB f32\n", x.nbytes() as f64 / (1024.0 * 1024.0));

    // ---- 1. QR baseline ------------------------------------------------
    let (a_qr, t_qr) = time_once(|| lstsq_qr(&x, &y).expect("qr"));
    println!("[1/4] QR baseline        {:>10}   mape={:.2e}", fmt_seconds(t_qr), mape(&a_qr, &a_true));

    // ---- 2. native SolveBak --------------------------------------------
    let mut o = SolveOptions::accurate();
    o.max_sweeps = 200;
    let (rep_bak, t_bak) = time_once(|| solve_bak(&x, &y, &o));
    println!(
        "[2/4] SolveBak (Alg 1)   {:>10}   sweeps={} stop={:?} mape={:.2e}",
        fmt_seconds(t_bak), rep_bak.sweeps, rep_bak.stop, mape(&rep_bak.a, &a_true)
    );
    println!("      residual curve (per sweep, ||e||^2):");
    for (i, r2) in rep_bak.history.iter().enumerate() {
        if i < 8 || i + 1 == rep_bak.history.len() {
            println!("        sweep {:>3}: {:.6e}", i + 1, r2);
        } else if i == 8 {
            println!("        ...");
        }
    }

    // ---- 3. native SolveBakP (threaded) ---------------------------------
    let mut op = SolveOptions::accurate();
    op.max_sweeps = 200;
    op.thr = 64;
    op.threads = solvebak::linalg::blas2::num_threads();
    let (rep_bakp, t_bakp) = time_once(|| solve_bakp(&x, &y, &op));
    println!(
        "[3/4] SolveBakP (Alg 2)  {:>10}   sweeps={} thr={} threads={} mape={:.2e}",
        fmt_seconds(t_bakp), rep_bakp.sweeps, op.thr, op.threads, mape(&rep_bakp.a, &a_true)
    );

    // ---- 4. coordinator -> PJRT artifact --------------------------------
    // The PJRT path runs on the largest artifact bucket (8192x512); we
    // solve a bucket-sized slice of the same workload through the full
    // service stack to prove the layers compose.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        artifact_dir: Some("artifacts".into()),
        ..CoordinatorConfig::default()
    });
    let pobs = 8192.min(obs);
    let mut xs = Mat::zeros(pobs, vars);
    for j in 0..vars {
        xs.col_mut(j).copy_from_slice(&x.col(j)[..pobs]);
    }
    let ys = y[..pobs].to_vec();
    let a_slice_qr = lstsq_qr(&xs, &ys).expect("slice qr");
    let mut req = SolveRequest::new(1, Arc::new(xs), ys);
    req.backend = Backend::Pjrt;
    req.opts.max_sweeps = 400;
    req.opts.tol = 1e-6;
    let (out, t_pjrt) = time_once(|| coord.solve_blocking(req));
    match out.report {
        Ok(rep) => {
            println!(
                "[4/4] PJRT via service   {:>10}   sweeps={} stop={:?} backend={:?}",
                fmt_seconds(t_pjrt), rep.sweeps, rep.stop, out.backend
            );
            let agree = rel_l2(&rep.a, &a_slice_qr);
            println!("      agreement with QR on the same slice: rel_l2 = {agree:.2e}");
            assert!(agree < 0.05, "PJRT and QR disagree: {agree}");
        }
        Err(e) => println!("[4/4] PJRT via service   unavailable: {e} (run `make artifacts`)"),
    }
    println!("\nservice metrics: {}", coord.metrics().to_json().to_string());
    coord.shutdown();

    // ---- summary ---------------------------------------------------------
    println!("\n=== summary (full {obs}x{vars} problem) ===");
    println!("method      time         vs QR");
    println!("QR          {:>10}   1.0x", fmt_seconds(t_qr));
    println!("SolveBak    {:>10}   {:.1}x", fmt_seconds(t_bak), t_qr / t_bak);
    println!("SolveBakP   {:>10}   {:.1}x", fmt_seconds(t_bakp), t_qr / t_bakp);
    assert!(rel_l2(&rep_bak.a, &a_qr) < 2e-2, "BAK vs QR");
    assert!(rel_l2(&rep_bakp.a, &a_qr) < 2e-2, "BAKP vs QR");
    println!("all solutions agree to tolerance. E2E driver done.");
}
