//! The coordinator as a serving system: a pool of client threads firing
//! solve requests at the service, exercising routing (auto backend),
//! same-matrix batching, backpressure, and the metrics pipeline.
//!
//! ```sh
//! cargo run --release --example solver_service [-- --requests 64 --workers 4]
//! ```

use std::sync::Arc;

use solvebak::cli::Args;
use solvebak::coordinator::{Backend, Coordinator, CoordinatorConfig, SolveRequest};
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::rel_l2;
use solvebak::util::timer::fmt_seconds;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let n_requests = args.get_usize("requests", 64).unwrap();
    let workers = args.get_usize("workers", 4).unwrap();

    println!("starting coordinator: {workers} workers, PJRT artifacts if present");
    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers,
        artifact_dir: Some("artifacts".into()),
        ..CoordinatorConfig::default()
    }));
    if let Some(eng) = coord.engine() {
        println!("pjrt engine: {} ({} artifacts)", eng.platform(), eng.manifest().artifacts.len());
    }

    // Model pool: a few shared matrices of different shapes, like a
    // serving deployment hosting several models.
    let mut rng = Rng::seed(7);
    let shapes = [(2_000usize, 64usize), (256, 64), (800, 40), (64, 64)];
    let pool: Vec<Arc<Mat>> = shapes
        .iter()
        .map(|&(o, v)| Arc::new(Mat::randn(&mut rng, o, v)))
        .collect();

    // Client threads: each fires a burst of requests with planted truths
    // and validates its own responses.
    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            let coord = coord.clone();
            let pool = pool.clone();
            let per_client = n_requests / 4;
            std::thread::spawn(move || {
                let mut rng = Rng::seed(100 + c);
                let mut checked = 0usize;
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| {
                        let x = pool[(i + c as usize) % pool.len()].clone();
                        let a: Vec<f32> = (0..x.cols()).map(|_| rng.normal_f32()).collect();
                        let y = x.matvec(&a);
                        let mut req =
                            SolveRequest::new(c * 10_000 + i as u64, x, y);
                        req.backend = Backend::Auto;
                        req.opts = SolveOptions::accurate();
                        (a, coord.submit(req).expect("submit"))
                    })
                    .collect();
                for (a_true, rx) in rxs {
                    let out = rx.recv().expect("reply");
                    let rep = out.report.expect("solve ok");
                    assert!(
                        rel_l2(&rep.a, &a_true) < 5e-2,
                        "client {c}: backend {:?} err {}",
                        out.backend,
                        rel_l2(&rep.a, &a_true)
                    );
                    checked += 1;
                }
                checked
            })
        })
        .collect();

    let total: usize = clients.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {total} requests in {} -> {:.1} req/s",
        fmt_seconds(wall),
        total as f64 / wall
    );
    println!("metrics: {}", coord.metrics().to_json().to_string());
    println!("done.");
}
