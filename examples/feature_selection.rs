//! SolveBakF feature selection (Algorithm 3 / §8) on a realistic
//! sparse-signal regression: 20k observations, 500 candidate features, 8
//! true predictors buried in noise. Compares against forward stepwise
//! regression (the Figure-2 baseline) for both quality and time.
//!
//! ```sh
//! cargo run --release --example feature_selection
//! ```

use solvebak::baselines::stepwise_select;
use solvebak::bench::workload::{Workload, WorkloadSpec};
use solvebak::solver::{select_features_bakf, BakfOptions};
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    let (obs, vars, k) = (20_000, 500, 8);
    println!("workload: {obs} x {vars}, {k} planted features + 5% noise");
    let (w, support) = Workload::sparse_support(WorkloadSpec::new(obs, vars, 2024), k, 0.05);
    println!("planted support: {support:?}\n");

    // SolveBakF: one fused scoring pass per round.
    let (rep_f, t_f) = time_once(|| {
        select_features_bakf(&w.x, &w.y, &BakfOptions { max_feat: k, ..Default::default() })
    });
    let hits_f = rep_f.selected.iter().filter(|j| support.contains(j)).count();
    println!(
        "SolveBakF : {:>10}  selected {:?}  recovered {hits_f}/{k}",
        fmt_seconds(t_f), rep_f.selected
    );
    println!("  residual curve: {:?}", rep_f.history.iter().map(|r| format!("{r:.3e}")).collect::<Vec<_>>());

    // Stepwise baseline: refits every candidate every round.
    let (rep_s, t_s) = time_once(|| stepwise_select(&w.x, &w.y, k));
    let hits_s = rep_s.selected.iter().filter(|j| support.contains(j)).count();
    println!(
        "stepwise  : {:>10}  selected {:?}  recovered {hits_s}/{k}",
        fmt_seconds(t_s), rep_s.selected
    );

    println!("\nspeed-up: {:.1}x (Figure 2 regime; grows with vars)", t_s / t_f);
    assert!(hits_f >= k - 1, "SolveBakF must recover the signal");
    println!("done.");
}
