//! Direct PJRT runtime walk-through: load the AOT artifacts, inspect the
//! menu, and drive one solve sweep-by-sweep — the minimal template for
//! embedding the engine without the coordinator.
//!
//! ```sh
//! make artifacts && cargo run --release --example pjrt_solve
//! ```

use solvebak::linalg::Mat;
use solvebak::runtime::{ArtifactKind, Engine};
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::mape;
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    let engine = match Engine::new("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("platform: {}", engine.platform());
    println!("artifact menu:");
    for a in &engine.manifest().artifacts {
        println!("  {:<24} {:>10} {}x{} width={}", a.name, a.kind.as_str(), a.obs, a.vars, a.width);
    }

    let (t, n) = time_once(|| engine.warmup().expect("warmup"));
    println!("warmup: compiled {t} executables in {}", fmt_seconds(n));

    // Solve a 1024x128 system on its exact bucket.
    let mut rng = Rng::seed(11);
    let x = Mat::randn(&mut rng, 1024, 128);
    let a_true: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);

    let mut opts = SolveOptions::default();
    opts.max_sweeps = 300;
    opts.tol = 1e-6;
    let (out, secs) = time_once(|| {
        engine.solve(&x, &y, &opts, ArtifactKind::BakpSweep).expect("pjrt solve")
    });
    println!(
        "\nsolved 1024x128 via '{}' in {}: sweeps={} stop={:?} mape={:.2e}",
        out.artifact, fmt_seconds(secs), out.report.sweeps, out.report.stop,
        mape(&out.report.a, &a_true)
    );

    // Feature scoring through the score artifact.
    let scores = engine.feature_scores(&x, &y).expect("scores");
    let best = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(j, _)| j)
        .unwrap();
    println!("top-scored feature by the score artifact: {best}");
    println!("done.");
}
