//! Quickstart: the `Problem`/`Solver` API — validate one system, then run
//! it through several registered solvers and compare.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use solvebak::api::{registry, solver_for, Problem, SolverKind};
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::{mape, rel_l2};
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    // A 50k x 200 tall system with a planted exact solution.
    let (obs, vars) = (50_000, 200);
    let mut rng = Rng::seed(42);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);
    println!("system: {obs} x {vars} (tall, consistent), f32");

    // One validated problem, many solvers: shape/NaN checks happen once,
    // every backend sees the same clean inputs.
    let problem = Problem::new(&x, &y).expect("valid problem");
    let opts = SolveOptions::builder()
        .max_sweeps(1000)
        .tol(1e-6)
        .thr(50)
        .threads(solvebak::linalg::blas2::num_threads())
        .build();

    let mut bak = None;
    let mut qr = None;
    for kind in [SolverKind::Bak, SolverKind::Bakp, SolverKind::Cgls, SolverKind::Qr] {
        let solver = solver_for(kind).expect("registered");
        let (result, secs) = time_once(|| solver.solve(&problem, &opts));
        let rep = result.unwrap_or_else(|e| panic!("{} failed: {e}", solver.name()));
        println!(
            "{:<16}: {:>10}  sweeps={:<4} rel_resid={:.2e}  mape={:.2e}",
            solver.name(),
            fmt_seconds(secs),
            rep.sweeps,
            rep.rel_residual(),
            mape(&rep.a, &a_true),
        );
        match kind {
            SolverKind::Bak => bak = Some((rep.a, secs)),
            SolverKind::Qr => qr = Some((rep.a, secs)),
            _ => {}
        }
    }
    let (a_bak, t_bak) = bak.expect("bak ran");
    let (a_qr, t_qr) = qr.expect("qr ran");
    assert!(rel_l2(&a_bak, &a_qr) < 1e-2, "solvers agree");
    println!(
        "\nall solutions agree; speed-up of SolveBak vs QR: {:.1}x (paper Table 1 regime)",
        t_qr / t_bak
    );

    // The capability matrix, straight from the registry.
    println!("\nregistered solvers:");
    println!(
        "{:<16} {:>5} {:>9} {:>12} {:>10}",
        "kind", "wide", "iterative", "needs_square", "warm_start"
    );
    for s in registry() {
        let c = s.capabilities();
        println!(
            "{:<16} {:>5} {:>9} {:>12} {:>10}",
            s.name(), c.supports_wide, c.iterative, c.needs_square, c.warm_start
        );
    }
    println!("done.");
}
