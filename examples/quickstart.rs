//! Quickstart: solve one tall dense system three ways and compare.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use solvebak::baselines::qr::lstsq_qr;
use solvebak::linalg::Mat;
use solvebak::solver::{solve_bak, solve_bakp, SolveOptions};
use solvebak::util::rng::Rng;
use solvebak::util::stats::{mape, rel_l2};
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    // A 50k x 200 tall system with a planted exact solution.
    let (obs, vars) = (50_000, 200);
    let mut rng = Rng::seed(42);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);
    println!("system: {obs} x {vars} (tall, consistent), f32");

    // 1. The paper's Algorithm 1.
    let opts = SolveOptions::accurate();
    let (rep, secs) = time_once(|| solve_bak(&x, &y, &opts));
    println!(
        "SolveBak   : {:>10}  sweeps={:<4} rel_resid={:.2e}  mape={:.2e}",
        fmt_seconds(secs), rep.sweeps, rep.rel_residual(), mape(&rep.a, &a_true)
    );

    // 2. The parallel variant (Algorithm 2).
    let mut popts = SolveOptions::accurate();
    popts.thr = 50;
    popts.threads = solvebak::linalg::blas2::num_threads();
    let (repp, secsp) = time_once(|| solve_bakp(&x, &y, &popts));
    println!(
        "SolveBakP  : {:>10}  sweeps={:<4} rel_resid={:.2e}  mape={:.2e}",
        fmt_seconds(secsp), repp.sweeps, repp.rel_residual(), mape(&repp.a, &a_true)
    );

    // 3. The LAPACK-style baseline.
    let (a_qr, secsq) = time_once(|| lstsq_qr(&x, &y).expect("qr"));
    println!(
        "QR baseline: {:>10}  (exact direct solve)          mape={:.2e}",
        fmt_seconds(secsq), mape(&a_qr, &a_true)
    );

    println!(
        "\nspeed-up vs QR: SolveBak {:.1}x, SolveBakP {:.1}x  (paper Table 1 regime)",
        secsq / secs, secsq / secsp
    );
    assert!(rel_l2(&rep.a, &a_qr) < 1e-2, "solvers agree");
    println!("all three solutions agree. done.");
}
