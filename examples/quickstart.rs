//! Quickstart: the `Problem`/`Solver` API — validate one system, then run
//! it through several registered solvers and compare; then the same flow
//! on a sparse system (COO build -> CSC -> native O(nnz) solve vs the
//! densified run).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use solvebak::api::{registry, solver_for, Problem, SolverKind};
use solvebak::bench::workload::{SparseWorkload, WorkloadSpec};
use solvebak::linalg::Mat;
use solvebak::solver::SolveOptions;
use solvebak::util::rng::Rng;
use solvebak::util::stats::{mape, rel_l2};
use solvebak::util::timer::{fmt_seconds, time_once};

fn main() {
    // A 50k x 200 tall system with a planted exact solution.
    let (obs, vars) = (50_000, 200);
    let mut rng = Rng::seed(42);
    let x = Mat::randn(&mut rng, obs, vars);
    let a_true: Vec<f32> = (0..vars).map(|_| rng.normal_f32()).collect();
    let y = x.matvec(&a_true);
    println!("system: {obs} x {vars} (tall, consistent), f32");

    // One validated problem, many solvers: shape/NaN checks happen once,
    // every backend sees the same clean inputs.
    let problem = Problem::new(&x, &y).expect("valid problem");
    let opts = SolveOptions::builder()
        .max_sweeps(1000)
        .tol(1e-6)
        .thr(50)
        .threads(solvebak::linalg::blas2::num_threads())
        .build();

    let mut bak = None;
    let mut qr = None;
    for kind in [
        SolverKind::Bak,
        SolverKind::Bakp,
        SolverKind::BakPar, // block-parallel: honours opts.threads (--threads / PALLAS_THREADS)
        SolverKind::Cgls,
        SolverKind::Qr,
    ] {
        let solver = solver_for(kind).expect("registered");
        let (result, secs) = time_once(|| solver.solve(&problem, &opts));
        let rep = result.unwrap_or_else(|e| panic!("{} failed: {e}", solver.name()));
        println!(
            "{:<16}: {:>10}  sweeps={:<4} rel_resid={:.2e}  mape={:.2e}",
            solver.name(),
            fmt_seconds(secs),
            rep.sweeps,
            rep.rel_residual(),
            mape(&rep.a, &a_true),
        );
        match kind {
            SolverKind::Bak => bak = Some((rep.a, secs)),
            SolverKind::Qr => qr = Some((rep.a, secs)),
            _ => {}
        }
    }
    let (a_bak, t_bak) = bak.expect("bak ran");
    let (a_qr, t_qr) = qr.expect("qr ran");
    assert!(rel_l2(&a_bak, &a_qr) < 1e-2, "solvers agree");
    println!(
        "\nall solutions agree; speed-up of SolveBak vs QR: {:.1}x (paper Table 1 regime)",
        t_qr / t_bak
    );

    // ---- Sparse systems: COO triplets -> CSC -> native O(nnz) solve ----
    //
    // At 1% density a BAK sweep touches ~1% of the cells, so the native
    // sparse path should beat the same solve on the densified matrix.
    // Hand-built matrices go through sparse::CooBuilder (push triplets,
    // then .to_csc() — see the lib.rs "Sparse systems" docs); for the
    // demo we draw from the shared benchmark generator.
    let (s_obs, s_vars, density) = (20_000, 400, 0.01);
    let w = SparseWorkload::uniform(WorkloadSpec::new(s_obs, s_vars, 7), density);
    let (sx, sy, sa_true) = (w.x, w.y, w.a_true);
    println!(
        "\nsparse system: {s_obs} x {s_vars}, nnz={} (density {:.3})",
        sx.nnz(),
        sx.density()
    );

    let sparse_problem = Problem::new_sparse(&sx, &sy).expect("valid sparse problem");
    let solver = solver_for(SolverKind::Bak).expect("registered");
    let (res, t_sparse) = time_once(|| solver.solve(&sparse_problem, &opts));
    let rep = res.expect("sparse bak solves");
    println!(
        "bak (native sparse) : {:>10}  sweeps={:<4} mape={:.2e}",
        fmt_seconds(t_sparse),
        rep.sweeps,
        mape(&rep.a, &sa_true)
    );

    let dense_x = sx.to_dense();
    let dense_problem = Problem::new(&dense_x, &sy).expect("valid densified problem");
    let (res, t_dense) = time_once(|| solver.solve(&dense_problem, &opts));
    let rep_d = res.expect("densified bak solves");
    println!(
        "bak (densified)     : {:>10}  sweeps={:<4} mape={:.2e}",
        fmt_seconds(t_dense),
        rep_d.sweeps,
        mape(&rep_d.a, &sa_true)
    );
    println!(
        "sparse-vs-dense speed-up at density {:.0}%: {:.1}x",
        density * 100.0,
        t_dense / t_sparse
    );

    // The capability matrix, straight from the registry.
    println!("\nregistered solvers:");
    println!(
        "{:<16} {:>5} {:>9} {:>12} {:>10} {:>7} {:>9}",
        "kind", "wide", "iterative", "needs_square", "warm_start", "sparse", "parallel"
    );
    for s in registry() {
        let c = s.capabilities();
        println!(
            "{:<16} {:>5} {:>9} {:>12} {:>10} {:>7} {:>9}",
            s.name(),
            c.supports_wide,
            c.iterative,
            c.needs_square,
            c.warm_start,
            c.supports_sparse,
            c.supports_parallel
        );
    }
    println!("done.");
}
