"""L2 graph tests: whole-sweep / whole-solve semantics, convergence, shapes."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from .test_kernels import make_system


class TestColnormsInv:
    def test_values(self):
        x, _, _ = make_system(32, 8, seed=1)
        got = model.colnorms_inv(x)
        want = 1.0 / np.sum(np.asarray(x) ** 2, axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_zero_column_maps_to_zero(self):
        x = jnp.zeros((16, 4), jnp.float32).at[:, 0].set(1.0)
        got = np.asarray(model.colnorms_inv(x))
        assert got[0] == pytest.approx(1.0 / 16.0, rel=1e-6)
        assert (got[1:] == 0.0).all()


class TestBakSweepGraph:
    @pytest.mark.parametrize("obs,vars_,blk", [(64, 32, 8), (64, 32, 32), (128, 64, 16)])
    def test_matches_ref_sweep(self, obs, vars_, blk):
        x, y, _ = make_system(obs, vars_, seed=obs + blk, noise=0.1)
        cninv = model.colnorms_inv(x)
        a0 = jnp.zeros((vars_,), x.dtype)
        a_g, e_g, r2 = model.bak_sweep(x, cninv, a0, y, blk=blk)
        a_r, e_r = ref.bak_sweep(x, a0, y)
        np.testing.assert_allclose(a_g, a_r, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(e_g, e_r, rtol=3e-5, atol=3e-5)
        assert float(r2) == pytest.approx(float(jnp.sum(e_r * e_r)), rel=1e-4)

    def test_block_width_does_not_change_semantics(self):
        # Sequential CD is blocking-invariant: any blk gives the same sweep.
        x, y, _ = make_system(64, 32, seed=13)
        cninv = model.colnorms_inv(x)
        a0 = jnp.zeros((32,), x.dtype)
        outs = [model.bak_sweep(x, cninv, a0, y, blk=b)[0] for b in (4, 8, 16, 32)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=3e-5, atol=3e-5)


class TestBakpSolveGraph:
    def test_square_system_converges_to_exact(self):
        x, y, a_true = make_system(64, 64, seed=21)
        a, e, hist = model.bakp_solve(x, y, n_sweeps=600, thr=8)
        # Square full-rank: residual -> 0 (Theorem 1's exact case).
        assert float(jnp.sum(e * e)) < 1e-4 * float(jnp.sum(y * y))

    def test_tall_system_converges_to_lstsq(self):
        x, y, a_true = make_system(256, 16, seed=22, noise=0.5)
        a, e, hist = model.bakp_solve(x, y, n_sweeps=200, thr=4)
        a_ls = jnp.linalg.lstsq(x, y)[0]
        np.testing.assert_allclose(a, a_ls, rtol=2e-3, atol=2e-3)

    def test_wide_system_interpolates(self):
        # More unknowns than equations: xa = y can be met exactly.
        x, y, _ = make_system(16, 64, seed=23)
        a, e, hist = model.bakp_solve(x, y, n_sweeps=300, thr=8)
        assert float(jnp.max(jnp.abs(e))) < 1e-2

    def test_history_is_monotone_nonincreasing(self):
        x, y, _ = make_system(96, 48, seed=24, noise=0.3)
        _, _, hist = model.bakp_solve(x, y, n_sweeps=50, thr=8)
        h = np.asarray(hist)
        assert (h[1:] <= h[:-1] * (1 + 1e-5)).all()

    def test_history_length(self):
        x, y, _ = make_system(32, 16, seed=25)
        _, _, hist = model.bakp_solve(x, y, n_sweeps=7, thr=4)
        assert hist.shape == (7,)


class TestFeatureSelection:
    def test_scores_match_ref(self):
        x, y, _ = make_system(128, 32, seed=31, noise=0.2)
        cninv = model.colnorms_inv(x)
        np.testing.assert_allclose(
            model.feature_scores(x, cninv, y), ref.feature_scores(x, y),
            rtol=3e-5, atol=3e-5)

    def test_recovers_planted_support(self):
        # y from 3 planted columns + small noise: greedy selection must
        # recover exactly those 3 columns first.
        k = jax.random.PRNGKey(32)
        x = jax.random.normal(k, (512, 64), jnp.float32)
        y = 2.0 * x[:, 7] - 1.5 * x[:, 23] + 0.8 * x[:, 41]
        y = y + 0.01 * jax.random.normal(jax.random.PRNGKey(33), (512,))
        idx, a, r2s = ref.select_features(x, y, 3)
        assert sorted(idx) == [7, 23, 41]
        assert r2s[-1] < 1e-3 * float(jnp.sum(y * y))

    def test_r2_history_decreases(self):
        x, y, _ = make_system(256, 32, seed=34, noise=1.0)
        _, _, r2s = ref.select_features(x, y, 8)
        assert all(b <= a * (1 + 1e-6) for a, b in zip(r2s, r2s[1:]))
