"""Hypothesis sweeps: kernel == oracle over random shapes/values/dtypes.

Property-based L1 coverage per the repro guide: shapes and dtypes are drawn
by hypothesis, correctness asserted against kernels/ref.py.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import bak_sweep as bak
from compile.kernels import bakp_block as bakp
from compile.kernels import score

SETTINGS = dict(max_examples=25, deadline=None)


def draw_system(seed, obs, vars_, dtype):
    k = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(k)
    x = jax.random.normal(kx, (obs, vars_), dtype)
    y = jax.random.normal(ky, (obs,), dtype)
    return x, y


def tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       obs=st.integers(2, 96),
       blk=st.integers(1, 24),
       dtype=st.sampled_from([jnp.float32]))
def test_bak_sweep_matches_ref(seed, obs, blk, dtype):
    x, y = draw_system(seed, obs, blk, dtype)
    cninv = ref.safe_inv(ref.colnorms_sq(x))
    a0 = jnp.zeros((blk,), dtype)
    a_k, e_k = bak.bak_sweep_block(x, cninv, a0, y)
    a_r, e_r = ref.bak_sweep(x, a0, y)
    np.testing.assert_allclose(np.asarray(a_k, np.float64),
                               np.asarray(a_r, np.float64), **tol(dtype))
    np.testing.assert_allclose(np.asarray(e_k, np.float64),
                               np.asarray(e_r, np.float64), **tol(dtype))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       obs=st.integers(2, 96),
       nblocks=st.integers(1, 6),
       thr=st.integers(1, 12),
       dtype=st.sampled_from([jnp.float32]))
def test_bakp_sweep_matches_ref(seed, obs, nblocks, thr, dtype):
    vars_ = nblocks * thr
    x, y = draw_system(seed, obs, vars_, dtype)
    cninv = ref.safe_inv(ref.colnorms_sq(x))
    a0 = jnp.zeros((vars_,), dtype)
    a_k, e_k = bakp.bakp_sweep(x, cninv, a0, y, thr)
    a_r, e_r = ref.bakp_sweep(x, a0, y, thr)
    np.testing.assert_allclose(np.asarray(a_k, np.float64),
                               np.asarray(a_r, np.float64), **tol(dtype))
    np.testing.assert_allclose(np.asarray(e_k, np.float64),
                               np.asarray(e_r, np.float64), **tol(dtype))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       obs=st.integers(2, 128),
       vars_=st.integers(1, 64),
       dtype=st.sampled_from([jnp.float32]))
def test_score_matches_ref(seed, obs, vars_, dtype):
    x, e = draw_system(seed, obs, vars_, dtype)
    cninv = ref.safe_inv(ref.colnorms_sq(x))
    np.testing.assert_allclose(
        np.asarray(score.feature_scores(x, cninv, e), np.float64),
        np.asarray(ref.feature_scores(x, e), np.float64), **tol(dtype))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       obs=st.integers(4, 64),
       vars_=st.integers(2, 32))
def test_sweep_never_increases_residual(seed, obs, vars_):
    # Theorem 1's monotonicity, property-based: holds for ANY system,
    # including rank-deficient and inconsistent ones.
    x, y = draw_system(seed, obs, vars_, jnp.float32)
    cninv = ref.safe_inv(ref.colnorms_sq(x))
    a0 = jnp.zeros((vars_,), jnp.float32)
    _, e1 = bak.bak_sweep_block(x, cninv, a0, y)
    r0 = float(jnp.sum(y * y))
    r1 = float(jnp.sum(e1 * e1))
    assert r1 <= r0 * (1 + 1e-5) + 1e-6
