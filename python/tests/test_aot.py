"""AOT path tests: lowering produces valid, well-formed HLO text."""

import json
import os

import pytest

from compile import aot


class TestLowering:
    @pytest.mark.parametrize("kind,obs,vars_,width", aot.QUICK_MENU)
    def test_lower_entry_produces_hlo_text(self, kind, obs, vars_, width):
        lowered, ins, outs = aot.lower_entry(kind, obs, vars_, width)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # Tuple return (return_tuple=True) so the Rust side can to_tuple().
        assert len(ins) >= 1 and len(outs) >= 1

    def test_hlo_has_expected_parameter_count(self):
        lowered, ins, _ = aot.lower_entry("bakp_sweep", 256, 64, 32)
        text = aot.to_hlo_text(lowered)
        # One parameter instruction per input in the entry computation.
        entry = text[text.index("ENTRY"):]
        n_params = entry.count("parameter(")
        assert n_params == len(ins) == 4

    def test_shapes_appear_in_entry(self):
        lowered, _, _ = aot.lower_entry("score", 256, 64, 0)
        text = aot.to_hlo_text(lowered)
        assert "f32[256,64]" in text
        assert "f32[64]" in text

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            aot.lower_entry("nope", 8, 8, 0)


class TestManifest:
    def test_manifest_written(self, tmp_path):
        import subprocess, sys
        out = tmp_path / "artifacts"
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
        man = json.loads((out / "manifest.json").read_text())
        assert man["version"] == 1
        names = {a["name"] for a in man["artifacts"]}
        assert "bakp_sweep_256x64" in names
        for a in man["artifacts"]:
            assert (out / a["file"]).exists()
            assert a["dtype"] == "f32"
