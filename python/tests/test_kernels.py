"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel must match the pure-jnp transliteration of the paper's
algorithms (kernels/ref.py) to tight tolerance across shapes and dtypes.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import bak_sweep as bak
from compile.kernels import bakp_block as bakp
from compile.kernels import score


def make_system(obs, vars_, seed=0, dtype=jnp.float32, noise=0.0):
    k = jax.random.PRNGKey(seed)
    kx, ka, kn = jax.random.split(k, 3)
    x = jax.random.normal(kx, (obs, vars_), dtype)
    a_true = jax.random.normal(ka, (vars_,), dtype)
    y = x @ a_true
    if noise:
        y = y + noise * jax.random.normal(kn, (obs,), dtype)
    return x, y, a_true


class TestBakSweepKernel:
    @pytest.mark.parametrize("obs,blk", [(16, 4), (64, 16), (128, 32), (256, 64)])
    def test_matches_sequential_ref(self, obs, blk):
        x, y, _ = make_system(obs, blk, seed=obs + blk)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a0 = jnp.zeros((blk,), x.dtype)
        a_k, e_k = bak.bak_sweep_block(x, cninv, a0, y)
        a_r, e_r = ref.bak_sweep(x, a0, y)
        np.testing.assert_allclose(a_k, a_r, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(e_k, e_r, rtol=2e-5, atol=2e-5)

    def test_nonzero_initial_guess(self):
        x, y, _ = make_system(64, 16, seed=7)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a0 = jnp.ones((16,), x.dtype) * 0.5
        e0 = y - x @ a0
        a_k, e_k = bak.bak_sweep_block(x, cninv, a0, e0)
        a_r, e_r = ref.bak_sweep(x, a0, e0)
        np.testing.assert_allclose(a_k, a_r, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(e_k, e_r, rtol=2e-5, atol=2e-5)

    def test_residual_never_increases(self):
        x, y, _ = make_system(48, 12, seed=3, noise=0.5)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a = jnp.zeros((12,), x.dtype)
        e = y
        prev = float(jnp.sum(e * e))
        for _ in range(5):
            a, e = bak.bak_sweep_block(x, cninv, a, e)
            cur = float(jnp.sum(e * e))
            assert cur <= prev * (1 + 1e-6)
            prev = cur

    def test_zero_column_is_skipped(self):
        x, y, _ = make_system(32, 8, seed=11)
        x = x.at[:, 3].set(0.0)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a0 = jnp.zeros((8,), x.dtype)
        a_k, e_k = bak.bak_sweep_block(x, cninv, a0, y)
        assert float(a_k[3]) == 0.0
        assert np.isfinite(np.asarray(e_k)).all()

    def test_consistency_e_tracks_a(self):
        # Invariant: e == y - x a after any number of sweeps.
        x, y, _ = make_system(40, 10, seed=5, noise=0.1)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a = jnp.zeros((10,), x.dtype)
        e = y
        for _ in range(3):
            a, e = bak.bak_sweep_block(x, cninv, a, e)
        np.testing.assert_allclose(e, y - x @ a, rtol=1e-4, atol=1e-4)


class TestBakpBlockKernel:
    @pytest.mark.parametrize("obs,vars_,thr", [(32, 8, 4), (64, 32, 8), (128, 64, 16)])
    def test_block_matches_ref(self, obs, vars_, thr):
        x, y, _ = make_system(obs, vars_, seed=obs)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        xb = x[:, :thr]
        da_k, e_k = bakp.bakp_block(xb, cninv[:thr], y)
        a_r, e_r = ref.bakp_block_step(x, jnp.zeros((vars_,), x.dtype), y, 0, thr)
        np.testing.assert_allclose(da_k, a_r[:thr], rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(e_k, e_r, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("obs,vars_,thr", [(32, 16, 4), (64, 32, 32), (128, 64, 8)])
    def test_full_sweep_matches_ref(self, obs, vars_, thr):
        x, y, _ = make_system(obs, vars_, seed=obs + thr, noise=0.2)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a0 = jnp.zeros((vars_,), x.dtype)
        a_k, e_k = bakp.bakp_sweep(x, cninv, a0, y, thr)
        a_r, e_r = ref.bakp_sweep(x, a0, y, thr)
        np.testing.assert_allclose(a_k, a_r, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(e_k, e_r, rtol=2e-5, atol=2e-5)

    def test_thr_equals_one_is_sequential_bak(self):
        # With thr=1 Algorithm 2 degenerates to Algorithm 1 exactly.
        x, y, _ = make_system(48, 8, seed=2)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a0 = jnp.zeros((8,), x.dtype)
        a_p, e_p = bakp.bakp_sweep(x, cninv, a0, y, 1)
        a_s, e_s = ref.bak_sweep(x, a0, y)
        np.testing.assert_allclose(a_p, a_s, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(e_p, e_s, rtol=2e-5, atol=2e-5)

    def test_stale_error_within_block(self):
        # The defining property of Algorithm 2: inside a block, every da_k
        # is computed against the same pre-block error.
        x, y, _ = make_system(32, 4, seed=9)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        da, _ = bakp.bakp_block(x, cninv, y)
        expect = (y @ x) * cninv          # all against stale e == y
        np.testing.assert_allclose(da, expect, rtol=2e-5, atol=2e-5)

    def test_residual_decreases_when_thr_small(self):
        # Paper: converges "if the thr parameter is small with respect to
        # the vars".
        x, y, _ = make_system(128, 64, seed=1, noise=0.3)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        a = jnp.zeros((64,), x.dtype)
        e = y
        prev = float(jnp.sum(e * e))
        for _ in range(10):
            a, e = bakp.bakp_sweep(x, cninv, a, e, 8)
            cur = float(jnp.sum(e * e))
            assert cur <= prev * (1 + 1e-5)
            prev = cur


class TestScoreKernel:
    @pytest.mark.parametrize("obs,vars_", [(32, 8), (128, 64), (256, 100)])
    def test_matches_ref(self, obs, vars_):
        x, y, _ = make_system(obs, vars_, seed=vars_, noise=0.4)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        s_k = score.feature_scores(x, cninv, y)
        s_r = ref.feature_scores(x, y)
        np.testing.assert_allclose(s_k, s_r, rtol=3e-5, atol=3e-5)

    def test_score_is_exact_error_reduction(self):
        # score_j must equal sum(e^2) - sum(e'^2) after a single BAK step
        # on column j.
        x, y, _ = make_system(64, 6, seed=4, noise=0.2)
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        s = np.asarray(score.feature_scores(x, cninv, y))
        r2_0 = float(jnp.sum(y * y))
        for j in range(6):
            a0 = jnp.zeros((6,), x.dtype)
            _, e1 = ref.bak_column_step(x, a0, y, j)
            drop = r2_0 - float(jnp.sum(e1 * e1))
            np.testing.assert_allclose(s[j], drop, rtol=1e-3, atol=1e-3)

    def test_planted_feature_wins(self):
        # y built from a single column -> that column must get the top score.
        x, _, _ = make_system(128, 16, seed=8)
        y = 3.0 * x[:, 5]
        cninv = ref.safe_inv(ref.colnorms_sq(x))
        s = np.asarray(score.feature_scores(x, cninv, y))
        assert int(np.argmax(s)) == 5
