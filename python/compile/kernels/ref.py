"""Pure-jnp oracles for the SolveBak family.

These are direct transliterations of Algorithms 1-3 of the paper
("Algorithmic Solution for Non-Square, Dense Systems of Linear Equations",
Bakas 2021) with no Pallas, no blocking tricks, no cleverness. Every Pallas
kernel and every Rust implementation is validated against these.

Notation follows the paper: ``x`` is (obs, vars), ``y`` is (obs,),
``a`` is (vars,), ``e = y - x a`` is the running residual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def colnorms_sq(x: jax.Array) -> jax.Array:
    """<x_j, x_j> for every column j. Shape (vars,)."""
    return jnp.sum(x * x, axis=0)


def safe_inv(v: jax.Array) -> jax.Array:
    """1/v with 0 mapped to 0 (a zero column contributes no update)."""
    return jnp.where(v > 0, 1.0 / jnp.where(v > 0, v, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Algorithm 1 — SolveBak (sequential cyclic coordinate descent)
# ---------------------------------------------------------------------------

def bak_column_step(x, a, e, j):
    """One line-5..7 step of Algorithm 1 for column j."""
    xj = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0]
    nrm = jnp.dot(xj, xj)
    da = jnp.where(nrm > 0, jnp.dot(xj, e) / jnp.where(nrm > 0, nrm, 1.0), 0.0)
    e = e - xj * da
    a = jax.lax.dynamic_update_index_in_dim(a, a[j] + da, j, axis=0)
    return a, e


def bak_sweep(x, a, e):
    """One full inner loop (lines 4-8) of Algorithm 1: j = 0..vars-1."""
    vars_ = x.shape[1]

    def body(j, carry):
        a, e = carry
        return bak_column_step(x, a, e, j)

    return jax.lax.fori_loop(0, vars_, body, (a, e))


def solve_bak(x, y, n_sweeps: int):
    """Algorithm 1 in full: returns (a, e, r2_history)."""
    a = jnp.zeros((x.shape[1],), x.dtype)
    e = y

    def step(carry, _):
        a, e = carry
        a, e = bak_sweep(x, a, e)
        return (a, e), jnp.sum(e * e)

    (a, e), hist = jax.lax.scan(step, (a, e), None, length=n_sweeps)
    return a, e, hist


# ---------------------------------------------------------------------------
# Algorithm 2 — SolveBakP (block-parallel with stale errors inside a block)
# ---------------------------------------------------------------------------

def bakp_block_step(x, a, e, j0, thr: int):
    """Lines 6-9 of Algorithm 2 for the block of columns [j0, j0+thr).

    All da_k inside the block are computed against the SAME (stale) error
    vector — that is the paper's parallelisation — and the error is then
    refreshed once with the block matvec of line 9.
    """
    xb = jax.lax.dynamic_slice_in_dim(x, j0, thr, axis=1)  # (obs, thr)
    nrm = jnp.sum(xb * xb, axis=0)                         # (thr,)
    da = (e @ xb) * safe_inv(nrm)                          # (thr,)
    e = e - xb @ da
    a = jax.lax.dynamic_update_slice_in_dim(
        a, jax.lax.dynamic_slice_in_dim(a, j0, thr) + da, j0, axis=0
    )
    return a, e


def bakp_sweep(x, a, e, thr: int):
    """One outer-j pass (lines 5-10) of Algorithm 2. vars % thr == 0."""
    vars_ = x.shape[1]
    assert vars_ % thr == 0, "reference requires thr | vars"

    def body(b, carry):
        a, e = carry
        return bakp_block_step(x, a, e, b * thr, thr)

    return jax.lax.fori_loop(0, vars_ // thr, body, (a, e))


def solve_bakp(x, y, n_sweeps: int, thr: int):
    """Algorithm 2 in full: returns (a, e, r2_history)."""
    a = jnp.zeros((x.shape[1],), x.dtype)
    e = y

    def step(carry, _):
        a, e = carry
        a, e = bakp_sweep(x, a, e, thr)
        return (a, e), jnp.sum(e * e)

    (a, e), hist = jax.lax.scan(step, (a, e), None, length=n_sweeps)
    return a, e, hist


# ---------------------------------------------------------------------------
# Algorithm 3 — SolveBakF (greedy feature selection)
# ---------------------------------------------------------------------------

def feature_scores(x, e):
    """Per-feature squared-error reduction of a single BAK step.

    Fitting da_j = <x_j,e>/<x_j,x_j> reduces sum(e^2) by exactly
    <x_j,e>^2 / <x_j,x_j>  (the regression sum of squares), so the
    feature minimising the residual (Alg. 3 line 5) is the argmax of this.
    """
    num = e @ x                    # (vars,)
    return num * num * safe_inv(colnorms_sq(x))


def least_squares_refit(xs, y):
    """Line 7 of Algorithm 3: exact LS refit on the selected columns."""
    g = xs.T @ xs
    rhs = xs.T @ y
    # Small k x k system; solve with jnp (the Rust side uses Cholesky).
    return jnp.linalg.solve(g + 1e-12 * jnp.eye(g.shape[0], dtype=xs.dtype), rhs)


def select_features(x, y, max_feat: int):
    """Algorithm 3: returns (indices, coeffs, r2_history). Python loop —
    used as oracle only (max_feat small)."""
    e = y
    idx: list[int] = []
    r2s: list[float] = []
    a = jnp.zeros((0,), x.dtype)
    for _ in range(max_feat):
        scores = feature_scores(x, e)
        # Never pick the same feature twice.
        if idx:
            scores = scores.at[jnp.array(idx)].set(-jnp.inf)
        j = int(jnp.argmax(scores))
        idx.append(j)
        xs = x[:, jnp.array(idx)]
        a = least_squares_refit(xs, y)
        e = y - xs @ a
        r2s.append(float(jnp.sum(e * e)))
    return idx, a, r2s
