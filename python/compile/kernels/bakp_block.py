"""L1 Pallas kernel: SolveBakP block update (Algorithm 2 lines 6-9).

This is the *performance* kernel. The paper parallelises by computing all
``thr`` coordinate steps of a block against the SAME stale error vector and
refreshing the error once per block. On TPU that is exactly two MXU
contractions per block:

    da_blk = (x_blk^T e) * cninv_blk        # (blk,obs)x(obs) matvec
    e'     = e - x_blk da_blk               # (obs,blk)x(blk) matvec

Arithmetic intensity is ~2 FLOP per loaded element, so the kernel is
HBM-bandwidth bound (the paper's own BLAS-1 regime); block width thr maps
to the BlockSpec column tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bakp_block_kernel(x_ref, cninv_ref, e_ref, da_ref, e_out_ref):
    """da = (e @ x) * cninv; e' = e - x @ da."""
    x = x_ref[...]
    cninv = cninv_ref[...]
    e = e_ref[...]
    # Contractions in f32 accumulation (MXU-style: inputs may be bf16).
    da = jnp.dot(e, x, preferred_element_type=jnp.float32) * cninv
    da = da.astype(x.dtype)
    e_out_ref[...] = e - jnp.dot(x, da, preferred_element_type=jnp.float32).astype(x.dtype)
    da_ref[...] = da


def bakp_block(x_blk, cninv_blk, e):
    """One Algorithm-2 block update. Returns (da_blk, e')."""
    obs, blk = x_blk.shape
    return pl.pallas_call(
        _bakp_block_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((blk,), x_blk.dtype),
            jax.ShapeDtypeStruct((obs,), x_blk.dtype),
        ),
        interpret=True,
    )(x_blk, cninv_blk, e)


def _bakp_sweep_kernel(x_ref, cninv_ref, a_ref, e_ref, a_out_ref, e_out_ref,
                       *, thr: int):
    """Full BAKP sweep in a single kernel instance.

    Grid-free variant used when the whole (obs, vars) tile fits VMEM:
    loops over column blocks of width ``thr`` internally, each block being
    the two-matvec stale-error update above. Used by the AOT path so the
    entire sweep is one fused HLO region.
    """
    x = x_ref[...]
    cninv = cninv_ref[...]
    nblocks = x.shape[1] // thr

    def body(b, carry):
        a, e = carry
        j0 = b * thr
        xb = jax.lax.dynamic_slice_in_dim(x, j0, thr, axis=1)
        cb = jax.lax.dynamic_slice_in_dim(cninv, j0, thr, axis=0)
        da = jnp.dot(e, xb, preferred_element_type=jnp.float32) * cb
        da = da.astype(x.dtype)
        e = e - jnp.dot(xb, da, preferred_element_type=jnp.float32).astype(x.dtype)
        ab = jax.lax.dynamic_slice_in_dim(a, j0, thr, axis=0)
        a = jax.lax.dynamic_update_slice_in_dim(a, ab + da, j0, axis=0)
        return a, e

    a, e = jax.lax.fori_loop(0, nblocks, body, (a_ref[...], e_ref[...]))
    a_out_ref[...] = a
    e_out_ref[...] = e


def bakp_sweep(x, cninv, a, e, thr: int):
    """One full Algorithm-2 pass over all column blocks. vars % thr == 0.

    Returns (a', e').
    """
    obs, vars_ = x.shape
    assert vars_ % thr == 0, f"thr={thr} must divide vars={vars_}"
    import functools
    return pl.pallas_call(
        functools.partial(_bakp_sweep_kernel, thr=thr),
        out_shape=(
            jax.ShapeDtypeStruct((vars_,), x.dtype),
            jax.ShapeDtypeStruct((obs,), x.dtype),
        ),
        interpret=True,
    )(x, cninv, a, e)
