"""L1 Pallas kernel: exact sequential SolveBak sweep over one column block.

This is the *correctness-reference* kernel: it preserves Algorithm 1's
sequential semantics (each column update sees the error vector already
updated by every previous column). One kernel instance holds a
(obs x blk) tile of ``x`` plus the full error vector in VMEM and runs the
CD recurrence with a ``fori_loop``; the block loop lives at L2.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the paper's GPU argument
("only one column resident in device memory") becomes "only one column
*block* resident in VMEM". blk is chosen so obs*blk*4 bytes fits the VMEM
budget; the HBM->VMEM stream of successive blocks is what BlockSpec
expresses at L2.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls, and
interpret-mode lowering emits plain HLO that the Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bak_sweep_kernel(x_ref, cninv_ref, a_ref, e_ref, a_out_ref, e_out_ref):
    """Sequential CD over the blk columns of this block.

    x_ref:     (obs, blk) column block of the input matrix
    cninv_ref: (blk,)     1/<x_j,x_j> for the block's columns (0 for zero cols)
    a_ref:     (blk,)     current coefficients for the block's columns
    e_ref:     (obs,)     current residual e = y - x a   (full vector)
    outputs: updated (a_block, e).
    """
    x = x_ref[...]
    cninv = cninv_ref[...]
    a = a_ref[...]
    e = e_ref[...]
    blk = x.shape[1]

    def body(j, carry):
        a, e = carry
        xj = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0]
        da = jnp.dot(xj, e) * cninv[j]
        e = e - xj * da
        a = jax.lax.dynamic_update_index_in_dim(a, a[j] + da, j, axis=0)
        return a, e

    a, e = jax.lax.fori_loop(0, blk, body, (a, e))
    a_out_ref[...] = a
    e_out_ref[...] = e


@functools.partial(jax.jit, static_argnames=())
def bak_sweep_block(x_blk, cninv_blk, a_blk, e):
    """Run Algorithm 1 lines 4-8 over the columns of ``x_blk``.

    Returns (a_blk', e'). Exactly equivalent (up to f32 rounding order) to
    calling ref.bak_column_step for each column in order.
    """
    obs, blk = x_blk.shape
    return pl.pallas_call(
        _bak_sweep_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((blk,), x_blk.dtype),
            jax.ShapeDtypeStruct((obs,), x_blk.dtype),
        ),
        interpret=True,
    )(x_blk, cninv_blk, a_blk, e)
