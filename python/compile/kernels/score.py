"""L1 Pallas kernel: SolveBakF feature scoring (Algorithm 3 line 3-5).

For every feature j the squared-error reduction of a single BAK step is
the regression sum of squares <x_j,e>^2 / <x_j,x_j>; Algorithm 3's
argmin-error feature is the argmax of that score. Computing all scores at
once is one (vars,obs)x(obs) contraction plus elementwise ops — "easily
vectorised by basic BLAS functions" as the paper puts it; here it is a
single MXU contraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(x_ref, cninv_ref, e_ref, out_ref):
    x = x_ref[...]
    e = e_ref[...]
    num = jnp.dot(e, x, preferred_element_type=jnp.float32)
    out_ref[...] = (num * num * cninv_ref[...]).astype(x.dtype)


def feature_scores(x, cninv, e):
    """Score every feature: (vars,) array of error reductions."""
    obs, vars_ = x.shape
    return pl.pallas_call(
        _score_kernel,
        out_shape=jax.ShapeDtypeStruct((vars_,), x.dtype),
        interpret=True,
    )(x, cninv, e)
