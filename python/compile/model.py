"""L2: the JAX compute graphs that the Rust runtime executes.

Everything here composes the L1 Pallas kernels into whole-sweep / whole-solve
graphs with static shapes, then ``aot.py`` lowers them to HLO text. Python is
build-time only: the Rust coordinator calls the compiled artifacts.

Exported graphs (all pure, all static-shape):

  bak_sweep(x, cninv, a, e)            one sequential Algorithm-1 sweep
  bakp_sweep(x, cninv, a, e)           one Algorithm-2 sweep (thr static)
  bakp_solve(x, y)                     n_sweeps Algorithm-2 sweeps + history
  feature_scores(x, cninv, e)          Algorithm-3 scoring pass
  colnorms_inv(x)                      precompute 1/<x_j,x_j>

Sweep-granular artifacts are deliberate: the Rust side owns the convergence
loop so it can do the paper's tolerance early-break without re-lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import bak_sweep as _bak
from compile.kernels import bakp_block as _bakp
from compile.kernels import score as _score
from compile.kernels import ref as _ref


def colnorms_inv(x):
    """1/<x_j,x_j> per column, 0 for zero columns. Shape (vars,)."""
    return _ref.safe_inv(_ref.colnorms_sq(x))


def bak_sweep(x, cninv, a, e, *, blk: int = 64):
    """One full sequential SolveBak sweep (Algorithm 1 lines 4-8).

    The column-block loop lives here at L2; each block is one Pallas kernel
    instance (bak_sweep_block) preserving exact sequential semantics.
    vars % blk must be 0 (aot.py picks shapes accordingly).
    """
    obs, vars_ = x.shape
    assert vars_ % blk == 0, f"blk={blk} must divide vars={vars_}"
    nblocks = vars_ // blk

    def body(b, carry):
        a, e = carry
        j0 = b * blk
        xb = jax.lax.dynamic_slice_in_dim(x, j0, blk, axis=1)
        cb = jax.lax.dynamic_slice_in_dim(cninv, j0, blk, axis=0)
        ab = jax.lax.dynamic_slice_in_dim(a, j0, blk, axis=0)
        ab, e = _bak.bak_sweep_block(xb, cb, ab, e)
        a = jax.lax.dynamic_update_slice_in_dim(a, ab, j0, axis=0)
        return a, e

    a, e = jax.lax.fori_loop(0, nblocks, body, (a, e))
    return a, e, jnp.sum(e * e)


def bakp_sweep(x, cninv, a, e, *, thr: int = 64):
    """One full SolveBakP sweep (Algorithm 2 lines 5-10) as one kernel."""
    a, e = _bakp.bakp_sweep(x, cninv, a, e, thr)
    return a, e, jnp.sum(e * e)


def bakp_solve(x, y, *, n_sweeps: int = 32, thr: int = 64):
    """Full Algorithm-2 solve from a=0: returns (a, e, r2_history)."""
    cninv = colnorms_inv(x)
    a = jnp.zeros((x.shape[1],), x.dtype)
    e = y

    def step(carry, _):
        a, e = carry
        a, e = _bakp.bakp_sweep(x, cninv, a, e, thr)
        return (a, e), jnp.sum(e * e)

    (a, e), hist = jax.lax.scan(step, (a, e), None, length=n_sweeps)
    return a, e, hist


def feature_scores(x, cninv, e):
    """Algorithm-3 scoring pass over all features."""
    return _score.feature_scores(x, cninv, e)


# ---------------------------------------------------------------------------
# AOT entrypoints: tuples in, tuple out, fixed dtypes — what aot.py lowers.
# ---------------------------------------------------------------------------

def make_bak_sweep_fn(blk: int):
    def fn(x, cninv, a, e):
        return bak_sweep(x, cninv, a, e, blk=blk)
    return fn


def make_bakp_sweep_fn(thr: int):
    def fn(x, cninv, a, e):
        return bakp_sweep(x, cninv, a, e, thr=thr)
    return fn


def make_score_fn():
    def fn(x, cninv, e):
        return (feature_scores(x, cninv, e),)
    return fn


def make_colnorms_fn():
    def fn(x):
        return (colnorms_inv(x),)
    return fn
