"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` or serialized ``HloModuleProto`` — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never executes at request time.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (0.5.1-compatible)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# The artifact menu. Shapes are static per artifact; the Rust coordinator
# routes a request to the bucket it fits (runtime::registry). Sweep-granular
# so Rust owns the convergence loop + tolerance early-break.
#
# (kind, obs, vars, width) where width = blk for bak, thr for bakp.
QUICK_MENU = [
    ("bakp_sweep", 256, 64, 32),
    ("bak_sweep", 256, 64, 32),
    ("score", 256, 64, 0),
    ("colnorms", 256, 64, 0),
]

FULL_MENU = QUICK_MENU + [
    ("bakp_sweep", 1024, 128, 64),
    ("bak_sweep", 1024, 128, 64),
    ("score", 1024, 128, 0),
    ("colnorms", 1024, 128, 0),
    ("bakp_sweep", 4096, 256, 64),
    ("score", 4096, 256, 0),
    ("colnorms", 4096, 256, 0),
    ("bakp_sweep", 8192, 512, 128),
    ("colnorms", 8192, 512, 0),
]


def lower_entry(kind: str, obs: int, vars_: int, width: int):
    """Returns (lowered, inputs, outputs) for one menu entry."""
    if kind == "bak_sweep":
        fn = model.make_bak_sweep_fn(blk=width)
        args = (f32(obs, vars_), f32(vars_), f32(vars_), f32(obs))
        ins = ["x", "cninv", "a", "e"]
        outs = ["a", "e", "r2"]
    elif kind == "bakp_sweep":
        fn = model.make_bakp_sweep_fn(thr=width)
        args = (f32(obs, vars_), f32(vars_), f32(vars_), f32(obs))
        ins = ["x", "cninv", "a", "e"]
        outs = ["a", "e", "r2"]
    elif kind == "score":
        fn = model.make_score_fn()
        args = (f32(obs, vars_), f32(vars_), f32(obs))
        ins = ["x", "cninv", "e"]
        outs = ["scores"]
    elif kind == "colnorms":
        fn = model.make_colnorms_fn()
        args = (f32(obs, vars_),)
        ins = ["x"]
        outs = ["cninv"]
    else:
        raise ValueError(kind)
    return jax.jit(fn).lower(*args), ins, outs


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--quick", action="store_true",
                   help="only the smallest shape bucket (CI)")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    menu = QUICK_MENU if args.quick else FULL_MENU
    manifest = []
    for kind, obs, vars_, width in menu:
        name = f"{kind}_{obs}x{vars_}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        lowered, ins, outs = lower_entry(kind, obs, vars_, width)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest.append({
            "name": name,
            "kind": kind,
            "obs": obs,
            "vars": vars_,
            "width": width,
            "dtype": "f32",
            "file": name + ".hlo.txt",
            "inputs": ins,
            "outputs": outs,
        })
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
